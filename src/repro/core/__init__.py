"""repro.core — the paper's contribution: unified posit/IEEE-754 transprecision.

Public API:
  formats:  PositFmt, FloatFmt, get_format, P8_0..P16_3, F32, BF16
  codec:    posit_decode, posit_encode, quantize (bit-exact, dynamic es)
  pcsr:     OperandSlots (per-op), TransPolicy (per-run)
  fcvt:     Table-I conversion ops (static or traced es)
  alu:      true-posit integer add/mul (PERCIVAL-baseline) + fused quire ops
  dot:      posit_dot / posit_matmul_wx (fused / unfused / quire dataflows)
  quire:    exact Kulisch accumulator (QuireFmt, quire_* ops, quire_matmul)
"""
from repro.core.types import (  # noqa: F401
    BF16, ES_MAX, ES_MIN, F16, F32, Fmt, FloatFmt, P8_0, P8_1, P8_2, P8_3,
    P16_0, P16_1, P16_2, P16_3, PositFmt, compute_dtype_for, get_format,
)
from repro.core.codec import (  # noqa: F401
    decode, encode, posit_decode, posit_decode_to, posit_encode, quantize,
)
from repro.core.lut import (  # noqa: F401
    CODEC_IMPLS, decode_with_impl, encode_with_impl, lut_decode_p8,
    lut_decode_p16, lut_encode_p8, resolve_codec_impl,
)
from repro.core.pcsr import (  # noqa: F401
    DATAFLOWS, FP32_POLICY, P8_SERVE, P8_WEIGHTS, P16_QUIRE, P16_TRAIN,
    P16_WEIGHTS, ROLES, OperandSlots, TransPolicy,
)
from repro.core.convert import (  # noqa: F401
    fcvt_p8_p8, fcvt_p8_p16, fcvt_p8_s, fcvt_p16_p8, fcvt_p16_p16, fcvt_p16_s,
    fcvt_s_p8, fcvt_s_p16,
)
from repro.core.alu import (  # noqa: F401
    posit_add, posit_mul, posit_sub, qclr, qma, qms, qneg, qround,
)
from repro.core.dot import (  # noqa: F401
    ACTIVATIONS, FormatPlan, apply_epilogue, format_pair_plan, posit_dot,
    posit_gemv, posit_matmul_wx, posit_softmax,
)
from repro.core.pack import (  # noqa: F401
    pack_p8, packed_decode_p8, packed_half_k, split_activations, unpack_p8,
)
from repro.core.policy import (  # noqa: F401
    PRECISION_PRESETS, LayerRule, PrecisionPolicy, get_precision_policy,
)
from repro.core.quire import (  # noqa: F401
    QuireFmt, quire_accumulate, quire_add_posit, quire_dot, quire_from_posit,
    quire_is_nar, quire_matmul, quire_negate, quire_normalize, quire_read,
    quire_read_f32, quire_zero,
)
