"""Table I — custom fcvt.* conversion ops (the paper's ISA extension, as JAX ops).

The paper adds three instruction families in the F-extension encoding space
(funct5 0x10 / 0x12 / 0x11), each with an ``es`` field that selects either a
*static* es (encoded in the instruction) or the *dynamic* es held in pcsr.
Here: every op takes ``es`` as a Python int (static — "encoded in the
instruction") or a traced int32 scalar (dynamic — "read from pcsr"); the traced
form compiles once and serves all es values.

  fcvt.p8.s   / fcvt.p16.s    : FP32  -> P8/P16      -> fcvt_p8_s,  fcvt_p16_s
  fcvt.s.p8   / fcvt.s.p16    : P8/P16 -> FP32       -> fcvt_s_p8,  fcvt_s_p16
  fcvt.p8.p8  / fcvt.p8.p16   : posit -> posit       -> fcvt_p8_p8, fcvt_p8_p16
  fcvt.p16.p8 / fcvt.p16.p16    (cross precision/es)   fcvt_p16_p8, fcvt_p16_p16

posit->posit conversion passes through the FP32 datapath (decode is exact, so
there is exactly one rounding — bit-identical to exact-value conversion; see
ref_codec.ref_convert).
"""
from __future__ import annotations

import jax

from repro.core.codec import EsLike, posit_decode, posit_encode

__all__ = [
    "fcvt_p8_s", "fcvt_p16_s", "fcvt_s_p8", "fcvt_s_p16",
    "fcvt_p8_p8", "fcvt_p8_p16", "fcvt_p16_p8", "fcvt_p16_p16",
]


# ---- fcvt.pfmt.fmt : FP32 -> posit (funct5=0x10) --------------------------------

def fcvt_p8_s(x: jax.Array, es: EsLike = 0) -> jax.Array:
    """FP32 -> P(8, es)."""
    return posit_encode(x, 8, es)


def fcvt_p16_s(x: jax.Array, es: EsLike = 1) -> jax.Array:
    """FP32 -> P(16, es)."""
    return posit_encode(x, 16, es)


# ---- fcvt.fmt.pfmt : posit -> FP32 (funct5=0x12) --------------------------------

def fcvt_s_p8(codes: jax.Array, es: EsLike = 0) -> jax.Array:
    """P(8, es) -> FP32 (exact)."""
    return posit_decode(codes, 8, es)


def fcvt_s_p16(codes: jax.Array, es: EsLike = 1) -> jax.Array:
    """P(16, es) -> FP32 (exact)."""
    return posit_decode(codes, 16, es)


# ---- fcvt.pfmt.pfmt : posit -> posit (funct5=0x11) ------------------------------

def _pp(codes, n_in, es_in, n_out, es_out):
    return posit_encode(posit_decode(codes, n_in, es_in), n_out, es_out)


def fcvt_p8_p8(codes: jax.Array, es_in: EsLike, es_out: EsLike) -> jax.Array:
    """P(8, es_in) -> P(8, es_out): dynamic-es re-rounding within one precision."""
    return _pp(codes, 8, es_in, 8, es_out)


def fcvt_p8_p16(codes: jax.Array, es_in: EsLike = 1, es_out: EsLike = 0) -> jax.Array:
    """P(16, es_in) -> P(8, es_out). (rd is p8; rs1 is p16 — paper naming order.)"""
    return _pp(codes, 16, es_in, 8, es_out)


def fcvt_p16_p8(codes: jax.Array, es_in: EsLike = 0, es_out: EsLike = 1) -> jax.Array:
    """P(8, es_in) -> P(16, es_out). Exact (p8 values are a subset of p16)."""
    return _pp(codes, 8, es_in, 16, es_out)


def fcvt_p16_p16(codes: jax.Array, es_in: EsLike, es_out: EsLike) -> jax.Array:
    """P(16, es_in) -> P(16, es_out)."""
    return _pp(codes, 16, es_in, 16, es_out)
