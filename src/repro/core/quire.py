"""Software quire — an exact Kulisch accumulator for posit products.

The paper's lightweight PAU (and our codec+FPU path) rounds after every
add/mul, which is exactly where transprecision GEMM/reduction accuracy dies at
p8/p16. PERCIVAL shows the missing capability is a *quire*: a wide fixed-point
accumulator into which every posit product lands exactly, with one single
rounding at quire->posit readout. This module is that accumulator, emulated in
integer JAX so the same source runs through XLA and inside Pallas kernel
bodies (Mosaic: no int64, no clz — see ``codec._decode_fields``).

Representation (DESIGN.md §7):

  * A quire value is an int32 array whose **last axis** holds ``n_limbs + 1``
    limbs: ``n_limbs`` radix-2^16 digits (LSB first) plus one NaR flag limb.
    value = sum_i limb[i] * 2^(16*i - BIAS); any nonzero flag limb == NaR.
  * Digits are *lazy*: ``quire_accumulate`` adds signed 16-bit digit
    contributions (|digit| < 2^17) without propagating carries, so each call
    is cheap and int32 headroom allows up to ``MAX_DEFERRED`` accumulations
    between ``quire_normalize`` calls. Canonical form after normalize: digits
    in [0, 2^16) with the top limb carrying the (signed) remainder.
  * The binary point anchor ``BIAS`` is **static per nbits** (sized for
    es = ES_MAX), so ``es`` never changes the layout: one compiled executable
    serves every es in [0, 3], and operands of different es (or even different
    nbits, p8 x p16) can share one quire.
  * Width: every product of two posits P(n<=16, es<=3) lands entirely inside
    the digit array, with ``CARRY_GUARD`` bits of headroom above maxpos^2 —
    at least 2^CARRY_GUARD products accumulate with no possible overflow.

``quire_read`` converts back to a posit code with a single round-to-nearest-
even against the *exact* sum (guard/sticky computed from the full magnitude),
validated bit-for-bit against a Fraction-arithmetic oracle in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.codec import (
    EsLike, _decode_fields, _encode_fields, _es_u32, _floor_log2_small, _sigw,
    _u32, _U32,
)
from repro.core.types import ES_MAX, PositFmt

RADIX = 16          # bits per digit; int32 limbs leave lazy-carry headroom
CARRY_GUARD = 20    # MSB headroom: >= 2^20 products accumulate exactly
MAX_DEFERRED = 8192 # accumulate calls allowed between quire_normalize calls


def _static_smax(nbits: int) -> int:
    """Worst-case |scale| of a posit P(nbits, es<=ES_MAX): (n-2) * 2^ES_MAX."""
    return (nbits - 2) << ES_MAX


def _static_bias(nbits: int) -> int:
    """Quire bit position of weight 2^0 — the es-independent anchor.

    The smallest product bit of two P(n, es<=3) posits has weight
    2^-(2*smax + 2*(sigw-1)); anchoring there keeps every digit index >= 0.
    """
    return 2 * _static_smax(nbits) + 2 * (_sigw(nbits) - 1)


def _limb_count(nbits: int) -> int:
    # span: [-(2 smax + 2 (w-1)), 2 smax + 1 + CARRY_GUARD] plus a sign bit
    width = (2 * _static_smax(nbits) + 1 + CARRY_GUARD) + _static_bias(nbits) + 1
    return -(-width // RADIX)


@dataclasses.dataclass(frozen=True)
class QuireFmt:
    """Static descriptor of the quire serving posit format P(nbits, es).

    ``es`` is only the *default* exponent size for ops that take codes; the
    limb layout is sized for ES_MAX so es may be a traced scalar at op level
    (same contract as the codec — no retrace on es change).
    """

    nbits: int  # 8 or 16 — the widest operand format this quire serves
    es: int = 2

    def __post_init__(self):
        if self.nbits not in (8, 16):
            raise ValueError(f"quire nbits must be 8 or 16, got {self.nbits}")
        if not (0 <= self.es <= ES_MAX):
            raise ValueError(f"quire es must be in [0,{ES_MAX}], got {self.es}")

    @classmethod
    def for_posit(cls, fmt: PositFmt) -> "QuireFmt":
        return cls(fmt.nbits, fmt.es)

    @property
    def n_limbs(self) -> int:
        return _limb_count(self.nbits)

    @property
    def bias(self) -> int:
        return _static_bias(self.nbits)

    @property
    def limbs_axis(self) -> int:
        """Size of the trailing limb axis: digits + 1 NaR flag limb."""
        return self.n_limbs + 1

    @property
    def storage_bits(self) -> int:
        return self.n_limbs * RADIX


# =====================================================================
# digit generation: posit codes / products -> signed radix-2^16 digits
# =====================================================================

def _split_digits(p: jax.Array, offset: jax.Array):
    """uint32 value ``p`` (< 2^29) placed at quire bit ``offset`` (int32 >= 0)
    -> (limb index, three 16-bit digits occupying limbs idx, idx+1, idx+2)."""
    idx = offset >> 4
    s = (offset & 15).astype(_U32)
    d0 = p & _u32(0xFFFF)
    d1 = p >> _u32(16)
    t0 = d0 << s                      # <= 0xFFFF << 15 < 2^31
    t1 = (d1 << s) + (t0 >> _u32(16))
    g0 = (t0 & _u32(0xFFFF)).astype(jnp.int32)
    g1 = (t1 & _u32(0xFFFF)).astype(jnp.int32)
    g2 = (t1 >> _u32(16)).astype(jnp.int32)
    return idx, g0, g1, g2


def _product_parts(fields_a, fields_b, nbits_a: int, nbits_b: int,
                   bias: int, subtract: bool):
    """Decoded operand fields -> (sgn, idx, g0, g1, g2, nar) for one product.

    Layout-agnostic: the last-axis scatter (here) and the Pallas kernel's
    VMEM-scratch scatter both consume this.
    """
    na, sa, ga, za, ra = fields_a
    nb, sb, gb, zb, rb = fields_b
    neg = na ^ nb
    if subtract:
        neg = ~neg
    p = ga * gb  # < 2^28 (sig < 2^14 each)
    offset = (sa + sb + jnp.int32(
        bias - (_sigw(nbits_a) - 1) - (_sigw(nbits_b) - 1)))
    nar = ra | rb
    live = ~(za | zb | nar)
    sgn = jnp.where(live,
                    jnp.where(neg, jnp.int32(-1), jnp.int32(1)), jnp.int32(0))
    idx, g0, g1, g2 = _split_digits(p, offset)
    return sgn, idx, g0, g1, g2, nar


def _posit_parts(fields, nbits: int, bias: int, subtract: bool):
    """Decoded posit fields -> scatter parts for exact single-value injection."""
    neg, s, sig, z, r = fields
    if subtract:
        neg = ~neg
    offset = s + jnp.int32(bias - (_sigw(nbits) - 1))
    live = ~(z | r)
    sgn = jnp.where(live,
                    jnp.where(neg, jnp.int32(-1), jnp.int32(1)), jnp.int32(0))
    idx, g0, g1, g2 = _split_digits(sig, offset)
    return sgn, idx, g0, g1, g2, r


def _scatter(q: jax.Array, parts, n_limbs: int) -> jax.Array:
    """Add signed digit contributions into last-axis limbs (lazy, no carries)."""
    sgn, idx, g0, g1, g2, nar = parts
    L = n_limbs
    lids = lax.broadcasted_iota(jnp.int32, (1,) * max(q.ndim - 1, 0) + (L,),
                                max(q.ndim - 1, 0))
    def b(x):
        return x[..., None]
    contrib = (jnp.where(b(idx) == lids, b(g0), 0)
               + jnp.where(b(idx) == lids - 1, b(g1), 0)
               + jnp.where(b(idx) == lids - 2, b(g2), 0))
    limbs = q[..., :L] + b(sgn) * contrib
    flag = q[..., L:] | b(nar).astype(jnp.int32)
    return jnp.concatenate([limbs, jnp.broadcast_to(flag, limbs.shape[:-1] + (1,))],
                           axis=-1)


# =====================================================================
# public quire ops
# =====================================================================

def quire_zero(batch_shape, qfmt: QuireFmt) -> jax.Array:
    """A cleared quire (PERCIVAL ``qclr``): all digits and the NaR flag zero."""
    return jnp.zeros(tuple(batch_shape) + (qfmt.limbs_axis,), jnp.int32)


def quire_accumulate(q: jax.Array, a: jax.Array, b: jax.Array, qfmt: QuireFmt,
                     *, es_a: Optional[EsLike] = None,
                     es_b: Optional[EsLike] = None,
                     nbits_a: Optional[int] = None,
                     nbits_b: Optional[int] = None,
                     subtract: bool = False) -> jax.Array:
    """q +/- = a * b, exactly. a/b are posit codes broadcastable to q's batch.

    Digits are accumulated lazily: call ``quire_normalize`` at least every
    ``MAX_DEFERRED`` accumulations (``quire_read`` normalizes internally).
    Mixed precision is allowed (p8 operand x p16 operand into a p16 quire).
    """
    na_, nb_ = nbits_a or qfmt.nbits, nbits_b or qfmt.nbits
    ea = _es_u32(qfmt.es if es_a is None else es_a)
    eb = _es_u32(qfmt.es if es_b is None else es_b)
    parts = _product_parts(_decode_fields(a, na_, ea), _decode_fields(b, nb_, eb),
                           na_, nb_, qfmt.bias, subtract)
    return _scatter(q, parts, qfmt.n_limbs)


def quire_add_posit(q: jax.Array, codes: jax.Array, qfmt: QuireFmt, *,
                    es: Optional[EsLike] = None, nbits: Optional[int] = None,
                    subtract: bool = False) -> jax.Array:
    """q +/- = value(codes), exactly (every posit value is a quire value)."""
    n = nbits or qfmt.nbits
    esl = _es_u32(qfmt.es if es is None else es)
    parts = _posit_parts(_decode_fields(codes, n, esl), n, qfmt.bias, subtract)
    return _scatter(q, parts, qfmt.n_limbs)


def quire_from_posit(codes: jax.Array, qfmt: QuireFmt, *,
                     es: Optional[EsLike] = None,
                     nbits: Optional[int] = None) -> jax.Array:
    """Exact posit -> quire conversion (NaR sets the flag limb)."""
    return quire_add_posit(quire_zero(jnp.shape(codes), qfmt), codes, qfmt,
                           es=es, nbits=nbits)


def quire_negate(q: jax.Array, qfmt: QuireFmt) -> jax.Array:
    """Exact negation (PERCIVAL ``qneg``): digit-wise negate, flag preserved."""
    L = qfmt.n_limbs
    return jnp.concatenate([-q[..., :L], q[..., L:]], axis=-1)


def quire_normalize(q: jax.Array, qfmt: QuireFmt) -> jax.Array:
    """Propagate lazy carries -> canonical digits in [0, 2^16), signed top limb.

    Exact-value-preserving; also the required fix-up after integer ``psum``
    of quires (digit-wise sums of canonical quires stay in int32 for up to
    2^14 devices).
    """
    L = qfmt.n_limbs
    c = jnp.zeros_like(q[..., 0])
    outs = []
    for i in range(L - 1):
        t = q[..., i] + c
        outs.append(t & 0xFFFF)
        c = t >> RADIX  # arithmetic: exact floor-carry for negative t
    outs.append(q[..., L - 1] + c)
    outs.append(q[..., L])
    return jnp.stack(outs, axis=-1)


def quire_is_nar(q: jax.Array, qfmt: QuireFmt) -> jax.Array:
    return q[..., qfmt.n_limbs] != 0


def _readout_fields(q: jax.Array, qfmt: QuireFmt):
    """Normalize + extract (neg, scale:int32, frac_la hidden@31, sticky,
    is_zero, is_nar) from a quire — the shared front half of both readouts.

    Guard and sticky downstream see the *full* digit magnitude, so any
    rounding built on these fields is a single rounding of the exact sum.
    """
    L = qfmt.n_limbs
    q = quire_normalize(q, qfmt)
    top = q[..., L - 1]
    neg = top < 0
    # conditional negate, then one more carry ripple -> nonneg canonical digits
    mag = jnp.where(neg[..., None], -q[..., :L], q[..., :L])
    c = jnp.zeros_like(top)
    d = []
    for i in range(L):
        t = mag[..., i] + c
        d.append((t & 0xFFFF).astype(_U32))
        c = t >> RADIX

    # MSB position over all digits (ascending loop: highest nonzero digit wins)
    P = jnp.full(top.shape, -1, jnp.int32)
    for i, di in enumerate(d):
        h = _floor_log2_small(jnp.maximum(di, 1).astype(jnp.int32))
        P = jnp.where(di > 0, jnp.int32(16 * i) + h, P)
    i_top = P >> 4
    r = (P & 15).astype(_U32)

    # 48-bit window below the MSB (3 digits) + sticky of everything lower
    zero_d = jnp.zeros_like(d[0])
    D2, D1, D0 = zero_d, zero_d, zero_d
    sticky = jnp.zeros(top.shape, bool)
    for i, di in enumerate(d):
        D2 = jnp.where(i_top == i, di, D2)
        D1 = jnp.where(i_top == i + 1, di, D1)
        D0 = jnp.where(i_top == i + 2, di, D0)
        sticky = sticky | ((i_top > i + 2) & (di != 0))
    hi = (D2 << _u32(16)) | D1              # MSB (hidden bit) at position 16+r
    frac_la = (hi << (_u32(16) - r)) | (D0 >> r)
    sticky = sticky | ((D0 & ((_u32(1) << r) - 1)) != 0)

    scale = P - jnp.int32(qfmt.bias)
    return neg, scale, frac_la, sticky, P < 0, quire_is_nar(q, qfmt)


def quire_read(q: jax.Array, qfmt: QuireFmt, *,
               out_nbits: Optional[int] = None,
               es_out: Optional[EsLike] = None) -> jax.Array:
    """quire -> posit codes: the single terminal rounding (PERCIVAL ``qround``).

    RNE against the exact accumulated value — guard and sticky are computed
    from the full digit magnitude, so the result is bit-identical to rounding
    the infinitely-precise sum. Exact zero -> 0; flagged -> NaR; magnitudes
    beyond the posit range saturate to maxpos/minpos (never 0/NaR).
    ``out_nbits``/``es_out`` let a p16-quire read out in any posit format.
    """
    out_n = qfmt.nbits if out_nbits is None else out_nbits
    oesl = _es_u32(qfmt.es if es_out is None else es_out)
    neg, scale, frac_la, sticky, is_zero, is_nar = _readout_fields(q, qfmt)
    code = _encode_fields(neg, scale, frac_la, sticky, out_n, oesl)
    code = jnp.where(is_zero, _u32(0), code)                     # exact zero
    code = jnp.where(is_nar, _u32(1 << (out_n - 1)), code)
    return code.astype(jnp.uint8 if out_n == 8 else jnp.uint16)


def _f32_from_fields(neg: jax.Array, scale: jax.Array, frac_la: jax.Array,
                     sticky: jax.Array) -> jax.Array:
    """RNE-assemble a float32 from (sign, scale, fraction bits without the
    hidden bit left-aligned at 31, sticky) — the same field convention as
    ``_encode_fields``, rounded into IEEE instead of posit.

    Exact single rounding incl. subnormals; overflow -> +-inf, magnitudes
    below half the smallest subnormal -> +-0.  Mosaic-safe (uint32 only,
    every shift in [0, 31]).
    """
    # significand with the hidden bit at 31; the fraction LSB it displaces
    # (weight 2^-32) can only matter as sticky
    sig_la = _u32(0x80000000) | (frac_la >> _u32(1))
    sticky = sticky | ((frac_la & _u32(1)) != 0)
    # subnormal pre-shift: scale < -126 keeps fewer than 24 mantissa bits
    sh = jnp.clip(-126 - scale, 0, 24).astype(_U32)
    mant = (sig_la >> _u32(8)) >> sh
    guard = ((sig_la >> _u32(7)) >> sh) & _u32(1)
    low = sig_la & ((_u32(1) << (_u32(7) + sh)) - _u32(1))
    st = sticky | (low != 0)
    inc = (guard == 1) & (st | ((mant & 1) == 1))
    mant = mant + inc.astype(_U32)
    # exponent-field base: adding the hidden bit of `mant` lands the biased
    # exponent; a rounding carry to 2^24 increments it for free.  Subnormals
    # use base 0 (mant *is* the field; carry to 2^23 re-normalizes for free).
    base = jnp.where(sh > 0, jnp.int32(0), scale + 126)
    fbits = (base.astype(_U32) << _u32(23)) + mant
    fbits = jnp.where(scale >= 128, _u32(0x7F800000), fbits)     # overflow
    fbits = jnp.where(scale < -150, _u32(0), fbits)              # underflow
    fbits = fbits | (jnp.where(neg, _u32(1), _u32(0)) << _u32(31))
    return lax.bitcast_convert_type(fbits, jnp.float32)


def quire_read_f32(q: jax.Array, qfmt: QuireFmt) -> jax.Array:
    """quire -> float32: single RNE of the exact sum into the FPU domain.

    The readout used by fused epilogues (DESIGN.md §8): bias/activation run
    in f32 on a value that saw *no* accumulation rounding.  Exact zero -> +0;
    NaR -> NaN; |sum| beyond f32 range -> +-inf (the same overflow semantics
    a f32-accumulating fused GEMM would produce).
    """
    neg, scale, frac_la, sticky, is_zero, is_nar = _readout_fields(q, qfmt)
    v = _f32_from_fields(neg, scale, frac_la, sticky)
    v = jnp.where(is_zero, jnp.float32(0.0), v)
    nan = lax.bitcast_convert_type(
        jnp.full(v.shape, 0x7FC00000, dtype=_U32), jnp.float32)
    return jnp.where(is_nar, nan, v)


# =====================================================================
# quire dataflow: exact dot / GEMM (XLA path; Pallas kernel mirrors this)
# =====================================================================

def quire_matmul(a: jax.Array, b: jax.Array, fmt: PositFmt, *,
                 es_a: Optional[EsLike] = None, es_b: Optional[EsLike] = None,
                 nbits_a: Optional[int] = None, nbits_b: Optional[int] = None,
                 out_nbits: Optional[int] = None,
                 es_out: Optional[EsLike] = None,
                 block_k: int = 256,
                 as_float: bool = False) -> jax.Array:
    """Exact-accumulation GEMM: every a[i,k]*b[k,j] lands in a per-output
    quire; one rounding at readout. a: (M, K), b: (K, N) posit codes ->
    (M, N) posit codes. O(M*N*L) int32 state — the software analogue of
    PERCIVAL's per-lane quire register, not an MXU path. ``fmt`` is the widest
    operand format (it sizes the quire); ``nbits_a/nbits_b`` override per
    operand for mixed-precision GEMMs.  ``as_float=True`` reads out through
    ``quire_read_f32`` instead (f32 result, one rounding — the fused-epilogue
    entry point).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    na_, nb_ = nbits_a or fmt.nbits, nbits_b or fmt.nbits
    qf = QuireFmt(max(na_, nb_), fmt.es)
    ea = _es_u32(fmt.es if es_a is None else es_a)
    eb = _es_u32(fmt.es if es_b is None else es_b)
    eo = ea if es_out is None else _es_u32(es_out)

    bk = min(block_k, MAX_DEFERRED)
    pad = (-K) % bk
    if pad:  # zero codes contribute nothing to a quire
        a = jnp.pad(a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    nb = (K + pad) // bk
    a_blk = a.T.reshape(nb, bk, M)
    b_blk = b.reshape(nb, bk, N)

    def block(q, xs):
        ab, bb = xs  # (bk, M), (bk, N)

        def step(j, q):
            ak = lax.dynamic_index_in_dim(ab, j, 0, keepdims=False)
            bk_row = lax.dynamic_index_in_dim(bb, j, 0, keepdims=False)
            return quire_accumulate(q, ak[:, None], bk_row[None, :], qf,
                                    es_a=ea, es_b=eb, nbits_a=na_, nbits_b=nb_)

        q = lax.fori_loop(0, bk, step, q)
        return quire_normalize(q, qf), None

    q0 = quire_zero((M, N), qf)
    q, _ = lax.scan(block, q0, (a_blk, b_blk))
    if as_float:
        return quire_read_f32(q, qf)
    return quire_read(q, qf, out_nbits=out_nbits, es_out=eo)


def quire_dot(a: jax.Array, b: jax.Array, fmt: PositFmt, *,
              es: Optional[EsLike] = None, es_out: Optional[EsLike] = None,
              block_k: int = 256) -> jax.Array:
    """Exact dot product of two 1-D posit-code vectors -> one posit code."""
    assert a.ndim == b.ndim == 1, (a.shape, b.shape)
    out = quire_matmul(a[None, :], b[:, None], fmt, es_a=es, es_b=es,
                       es_out=es_out, block_k=block_k)
    return out[0, 0]
