"""Per-layer precision policies — the pcsr, scheduled over a model.

A single ``TransPolicy`` gives every linear layer the same weight format.
The paper's precision-scalability story (and the 2.54x GEMM headline) comes
from *mixing* formats: attention projections at p16 where accuracy is
sensitive, MLP weights at packed p8 where bytes dominate, independent es per
operand.  ``PrecisionPolicy`` expresses that as an ordered rule list mapping
layer *paths* (glob patterns over names like ``"blocks/attn/wq"``) to a
weight format + packed-lane flag, over a base ``TransPolicy`` that keeps
supplying every non-weight role (kv_cache, gradients, compute dtype, ...).

Resolution order (DESIGN.md §9):

1. rules are scanned **in declaration order**; the first pattern that
   ``fnmatch``-matches the layer path wins,
2. a matching rule replaces only ``weights`` / ``pack_weights`` on the base
   policy (a rule with ``weights=None`` pins the layer to the base format),
3. no match -> the base policy unchanged.

A ``PrecisionPolicy`` duck-types ``TransPolicy`` (attribute access for
non-weight roles delegates to the base), so the whole launch/model stack —
``make_train_step``, serving cache init, collectives — accepts one without
changes; only ``models.layers.resolve_policy`` (called with the layer path at
each linear call site) sees the per-layer view.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import functools
import json
from typing import Optional, Tuple

from repro.core.pcsr import TransPolicy
from repro.core.types import ES_MAX, ES_MIN, PositFmt, get_format


@dataclasses.dataclass(frozen=True)
class LayerRule:
    """One per-layer override: glob pattern -> (weight format, packed flag).

    ``bypass=True`` is the float escape hatch: the matching layer runs with
    ``weights=None`` (no posit quantization at all) regardless of the base
    policy's format.  It is the last rung of the numerics degradation ladder
    (``repro.ft.serving``, DESIGN.md §13) — distinct from ``weights=None``
    *without* bypass, which pins the layer to the base format.
    """

    pattern: str                        # fnmatch glob over the layer path
    weights: Optional[PositFmt] = None  # None = keep the base policy's format
    packed: bool = False                # packed-p8 lane storage (core/pack.py)
    bypass: bool = False                # True: force float (weights=None)

    def __post_init__(self):
        if self.packed and (self.weights is None or self.weights.nbits != 8):
            raise ValueError(
                f"packed rules require p8 weights, got {self.weights} "
                f"for pattern {self.pattern!r}")
        if self.bypass and self.weights is not None:
            raise ValueError(
                f"bypass rules take no weight format, got {self.weights} "
                f"for pattern {self.pattern!r}")


def _rule(pattern: str, fmt: Optional[str], packed: bool = False) -> LayerRule:
    f = get_format(fmt) if fmt is not None else None
    if f is not None and not isinstance(f, PositFmt):
        raise ValueError(f"layer rules take posit formats, got {fmt!r}")
    return LayerRule(pattern, f, packed)


def _pattern_matches(path: str, pattern: str) -> bool:
    """True when ``pattern`` fnmatch-matches ``path`` or any '/'-suffix of it.

    Layer paths appear in two spellings: the call-site logical path
    ("mlp/gate") and the param-tree path at quantize time
    ("blocks/mlp/gate").  Suffix matching makes an anchored rule like
    "mlp/gate=p8_0" resolve identically in both, so quantize-time and
    decode-time formats can never diverge.
    """
    if fnmatch.fnmatchcase(path, pattern):
        return True
    return fnmatch.fnmatchcase(path, "*/" + pattern)


@functools.lru_cache(maxsize=4096)
def _resolve(policy: "PrecisionPolicy", path: str) -> TransPolicy:
    rule = policy.rule_for(path)
    if rule is None:
        return policy.base
    if rule.bypass:
        # float escape hatch (degradation ladder's last rung): the layer
        # skips weight quantization entirely
        return dataclasses.replace(
            policy.base, weights=None, pack_weights=False)
    if rule.weights is None:
        # a weights=None rule: the layer keeps the base format (a None rule
        # *pins* the layer — it stops later rules from firing)
        return policy.base
    return dataclasses.replace(
        policy.base, weights=rule.weights, pack_weights=rule.packed)


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Ordered per-layer weight-format rules over a base ``TransPolicy``."""

    base: TransPolicy = TransPolicy()
    rules: Tuple[LayerRule, ...] = ()
    name: str = "custom"

    def rule_for(self, path: str) -> Optional[LayerRule]:
        for rule in self.rules:
            if _pattern_matches(path, rule.pattern):
                return rule
        return None

    def policy_for(self, path: str) -> TransPolicy:
        """The concrete TransPolicy a layer at ``path`` runs under."""
        return _resolve(self, path)

    def with_base(self, base: TransPolicy) -> "PrecisionPolicy":
        """Re-seat the rules over a different base policy (keeps the base's
        non-weight roles: kv_cache, gradients, compute dtype, ...)."""
        return dataclasses.replace(self, base=base)

    def describe(self) -> str:
        parts = [f"precision={self.name}", self.base.describe()]
        for r in self.rules:
            fmt = ("float" if r.bypass
                   else r.weights.name if r.weights else "base")
            parts.append(
                f"{r.pattern}->{fmt}{'(packed)' if r.packed else ''}")
        return " ".join(parts)

    def to_json(self) -> dict:
        """JSON-ready dict (schema DESIGN.md §11): name, base TransPolicy,
        ordered rules.  ``from_json`` inverts it; extra top-level keys (the
        calibration ``meta`` block) are ignored on load."""
        return {
            "kind": "repro/precision-policy",
            "version": 1,
            "name": self.name,
            "base": self.base.to_json(),
            "rules": [{
                "pattern": r.pattern,
                "weights": r.weights.name if r.weights is not None else None,
                "packed": r.packed,
                **({"bypass": True} if r.bypass else {}),
            } for r in self.rules],
        }

    @classmethod
    def from_json(cls, d: dict) -> "PrecisionPolicy":
        if d.get("kind", "repro/precision-policy") != "repro/precision-policy":
            raise ValueError(f"not a precision-policy document: {d.get('kind')!r}")
        for r in d.get("rules", ()):
            # reject typos loudly: a hand-edited {"weight": ...} rule would
            # otherwise silently degrade to a weights=None pin-to-base rule
            bad = set(r) - {"pattern", "weights", "packed", "bypass"}
            if bad or "pattern" not in r:
                raise ValueError(
                    f"malformed precision rule {r!r}: "
                    + (f"unknown keys {sorted(bad)}" if bad
                       else "missing 'pattern'"))
        rules = tuple(
            LayerRule(r["pattern"], None, bypass=True)
            if r.get("bypass") else
            _rule(r["pattern"], r.get("weights"),
                  packed=bool(r.get("packed", False)))
            for r in d.get("rules", ()))
        base = TransPolicy.from_json(d["base"]) if "base" in d else TransPolicy()
        return cls(base=base, rules=rules, name=d.get("name", "custom"))

    def __getattr__(self, item: str):
        # duck-type TransPolicy: non-weight attribute reads fall through to
        # the base (only called when normal dataclass lookup misses)
        if item.startswith("__"):
            raise AttributeError(item)
        return getattr(object.__getattribute__(self, "base"), item)


# ------------------------------------------------------------------ presets ----

def _preset(name: str, base: TransPolicy, *rules: LayerRule) -> "PrecisionPolicy":
    return PrecisionPolicy(base=base, rules=tuple(rules), name=name)


#: Named per-layer precision presets (launch/hillclimb search dimension,
#: ``serve.py --precision-policy``).  Every preset's weight schedule lives in
#: its *rules* (with a catch-all), never only in the base: ``with_base`` /
#: the ``base=`` overlay replaces the base wholesale (it supplies the
#: non-weight roles), and a schedule carried there would be silently lost.
PRECISION_PRESETS = {
    # every linear at p16_1 — the accuracy-first uniform configuration
    "uniform-p16": _preset(
        "uniform-p16", TransPolicy.from_names(weights="p16_1"),
        _rule("*", "p16_1"),
    ),
    # every linear at p8_0, bf16 MXU — the bytes-first uniform configuration
    "p8-weights": _preset(
        "p8-weights",
        TransPolicy.from_names(weights="p8_0", compute_dtype="bf16"),
        _rule("*", "p8_0"),
    ),
    # p8 weights in packed lanes: half the weight words through HBM/VMEM
    "p8-packed": _preset(
        "p8-packed",
        TransPolicy.from_names(weights="p8_0", compute_dtype="bf16",
                               pack_weights=True),
        _rule("*", "p8_0", packed=True),
    ),
    # the mixed profile: accuracy-sensitive attention projections (incl.
    # encoder-decoder self/cross attention) stay p16, byte-dominated
    # MLP/MoE/head weights drop to packed p8, everything else p16
    "attn-p16-mlp-p8": _preset(
        "attn-p16-mlp-p8", TransPolicy.from_names(weights="p16_1"),
        _rule("*attn*", "p16_1"),
        _rule("*self*", "p16_1"),
        _rule("*cross*", "p16_1"),
        _rule("*mlp*", "p8_0", packed=True),
        _rule("*moe*", "p8_0", packed=True),
        _rule("*ffn*", "p8_0", packed=True),
        _rule("lm_head*", "p8_0", packed=True),
        _rule("*", "p16_1"),
    ),
}


def parse_fmt_token(tok: str) -> PositFmt:
    """A rule's format token: ``p8_0`` | ``p16_1`` | ... with an optional
    dynamic-es override ``@es`` (``p8@2``, ``p16_1@3`` -> p16_3).

    Bare ``p8``/``p16`` require the ``@es`` suffix; es outside
    [ES_MIN, ES_MAX] or non-integer es raise ``ValueError`` (the pes CSR
    field is 3 bits wide but fp32-overflow bounds usable es, core/types.py).
    """
    tok = tok.strip()
    name, _, es_s = tok.partition("@")
    name = name.strip()
    if es_s:
        try:
            es = int(es_s.strip())
        except ValueError:
            raise ValueError(f"es in {tok!r} must be an integer, got {es_s!r}")
        if not (ES_MIN <= es <= ES_MAX):
            raise ValueError(
                f"es {es} out of range [{ES_MIN}, {ES_MAX}] in {tok!r}")
        if name in ("p8", "p16"):
            return PositFmt(int(name[1:]), es)
        f = get_format(name)
        if not isinstance(f, PositFmt):
            raise ValueError(f"@es only applies to posit formats, got {name!r}")
        return f.with_es(es)
    if name in ("p8", "p16"):
        raise ValueError(
            f"bare {name!r} needs an exponent size: {name}@es or {name}_es")
    f = get_format(name)
    if not isinstance(f, PositFmt):
        raise ValueError(f"layer rules take posit formats, got {name!r}")
    return f


def _load_policy_file(path: str) -> PrecisionPolicy:
    with open(path) as f:
        return PrecisionPolicy.from_json(json.load(f))


def get_precision_policy(name_or_spec: str,
                         base: Optional[TransPolicy] = None) -> PrecisionPolicy:
    """Look up a preset by name, load a saved artifact, or parse a rule spec.

    Three spellings, everywhere a precision policy is accepted::

        --precision-policy "attn-p16-mlp-p8"                        # preset
        --precision-policy "@experiments/cal.json"                  # artifact
        --precision-policy "*attn*=p16@2,*mlp*=p8@1:packed,*=p16_1" # spec

    Spec grammar: comma-separated ``pattern=fmt[@es][:packed]`` entries,
    applied in order (first match wins); ``@es`` overrides the exponent size
    (``parse_fmt_token``).  ``pattern=float`` is the bypass spelling (the
    layer skips weight quantization — the degradation ladder's last rung).
    ``base`` (when given) supplies every non-weight role — e.g. the serving
    ``--policy`` keeps its kv_cache/compute_dtype while the precision policy
    schedules the weights.
    """
    if name_or_spec.startswith("@"):
        pol = _load_policy_file(name_or_spec[1:])
        return pol if base is None else pol.with_base(base)
    if name_or_spec in PRECISION_PRESETS:
        pol = PRECISION_PRESETS[name_or_spec]
        return pol if base is None else pol.with_base(base)
    if "=" not in name_or_spec:
        raise KeyError(
            f"unknown precision policy {name_or_spec!r}; presets: "
            f"{sorted(PRECISION_PRESETS)} (or @artifact.json, or a "
            f"pattern=fmt[@es][:packed],... spec)")
    rules = []
    for part in name_or_spec.split(","):
        pattern, _, fmt = part.partition("=")
        if not fmt:
            raise ValueError(f"malformed precision rule {part!r}")
        fmt, _, mod = fmt.partition(":")
        if mod not in ("", "packed"):
            raise ValueError(f"unknown rule modifier {mod!r} in {part!r}")
        if fmt.strip() == "float":
            if mod:
                raise ValueError(f"float bypass takes no modifier: {part!r}")
            rules.append(LayerRule(pattern.strip(), None, bypass=True))
        else:
            rules.append(LayerRule(pattern.strip(), parse_fmt_token(fmt),
                                   packed=mod == "packed"))
    return PrecisionPolicy(base=base if base is not None else TransPolicy(),
                           rules=tuple(rules), name=name_or_spec)
