"""Paged posit KV cache: fixed-byte pages, block-hash prefix sharing, COW.

The slot-grid engine (DESIGN.md §10) allocates every slot a dense
``S_max``-row KV strip.  At serving scale that wastes exactly the capacity
the posit codecs buy: rows past a request's live length are dead bytes, and
requests sharing a system prompt store the same prefix codes once *per
slot*.  This module is the host-side allocator for the paged layout that
fixes both (DESIGN.md §14):

* **Fixed-byte pages.**  A block (page) is ``page_bytes`` of K+V storage per
  layer.  Token capacity follows the KV code width — the page geometry is
  ``kv_bits``-aware, so a packed-p8 page holds **2x the tokens of a p16
  page and 4x an f32 page** of the same byte size.  That is the paper's
  lightweight-posit pillar applied to cache *capacity*, not just footprint.
* **Prefix sharing.**  Full blocks written by prefill are content-addressed
  by a chained block hash over their token ids; a new request whose prompt
  starts with an already-cached chain maps those blocks into its table and
  bumps refcounts instead of storing duplicates.
* **Copy-on-write.**  ``fork_slot`` clones a live request by aliasing every
  block (parallel sampling / n-best).  The first divergent write into a
  shared tail block triggers :meth:`ensure_writable`: the writer gets a
  private copy, the other holders keep the original.
* **LRU reuse.**  Releasing a slot decrements refcounts; hashed blocks that
  hit refcount 0 are *retained* in an LRU of evictable blocks (a later
  request with the same prefix still hits), and are recycled only when the
  free list runs dry.

The manager is pure host bookkeeping (numpy + dicts): device pools and the
actual scatter/gather live in ``models.transformer.decode_step_paged`` and
``launch.paged_engine``.  Every mutation keeps the invariants checked by
:meth:`check_invariants` (tests/test_paged_kv.py exercises adversarial
admit/fork/evict orders against it).
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

__all__ = ["PageGeometry", "PagedKVCache", "PoolExhausted", "PrefixMatch",
           "ROOT_DIGEST"]

#: Chain digest of the empty token prefix (the hash-chain anchor).
ROOT_DIGEST = hashlib.blake2b(b"repro/paged-kv/root", digest_size=16).hexdigest()


class PoolExhausted(RuntimeError):
    """No free block and no evictable (refcount-0) cached block left."""


def _chain(parent_digest: str, tokens) -> str:
    """Chained block hash: digest of (parent chain, this block's token ids).

    Content addressing must cover the *whole prefix*, not just the block's
    own tokens — KV codes at a position depend on every earlier token
    (causal attention), so two blocks holding the same 16 tokens after
    different prefixes hold different codes.
    """
    h = hashlib.blake2b(bytes.fromhex(parent_digest), digest_size=16)
    h.update(np.asarray(tokens, np.int32).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class PageGeometry:
    """Byte-budgeted page layout for one KV cache.

    ``page_bytes`` is the per-layer K+V byte budget of one block; the token
    capacity ``block_tokens`` follows from the code width:

        block_tokens = page_bytes // (2 * n_kv * head_dim * code_bytes)

    so at a fixed page size, p8 codes (1 B) give 2x the tokens of p16 (2 B)
    and 4x of f32 (4 B) — the kv_bits-aware layout the paged capacity claim
    rests on.
    """

    n_layers: int
    n_kv: int
    head_dim: int
    code_bytes: int          # 1 = p8, 2 = p16/bf16, 4 = f32
    page_bytes: int = 16384

    def __post_init__(self):
        if self.code_bytes not in (1, 2, 4):
            raise ValueError(f"code_bytes must be 1|2|4, got {self.code_bytes}")
        if self.block_tokens < 1:
            raise ValueError(
                f"page_bytes {self.page_bytes} holds no tokens at "
                f"2*{self.n_kv}*{self.head_dim}*{self.code_bytes} B/token")

    @property
    def block_tokens(self) -> int:
        return self.page_bytes // (2 * self.n_kv * self.head_dim
                                   * self.code_bytes)

    def pool_bytes(self, n_blocks: int) -> int:
        """Device bytes of an ``n_blocks`` K+V pool (all layers)."""
        return (n_blocks * self.n_layers * 2 * self.n_kv * self.head_dim
                * self.block_tokens * self.code_bytes)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_tokens)

    def describe(self) -> str:
        """Config fingerprint line — part of the snapshot compatibility
        check (ft/serving.py): a snapshot taken under one page geometry must
        never restore into another."""
        return (f"paged(bt={self.block_tokens},L={self.n_layers},"
                f"kv={self.n_kv}x{self.head_dim},code_B={self.code_bytes},"
                f"page_B={self.page_bytes})")


@dataclasses.dataclass
class PrefixMatch:
    """Result of :meth:`PagedKVCache.match_prefix`."""

    bids: List[int]          # cached full blocks covering the prompt head
    n_tokens: int            # tokens covered (len(bids) * block_tokens)
    tail_digest: str         # chain digest after the matched blocks


class PagedKVCache:
    """Host-side allocator for one block pool (refcounts, hashes, tables).

    Block ids index the device pools ``(L, n_blocks, Hkv, bt, hd)``; the
    sentinel id ``n_blocks`` marks empty table entries (out-of-bounds on
    device, so scatters through it drop and gathers clamp into masked-off
    rows).
    """

    def __init__(self, geom: PageGeometry, *, n_blocks: int, max_slots: int):
        if n_blocks < 1:
            raise ValueError(f"need at least one block, got {n_blocks}")
        self.geom = geom
        self.n_blocks = n_blocks
        self.max_slots = max_slots
        self.sentinel = n_blocks
        self.refcount = np.zeros((n_blocks,), np.int32)
        self.free: List[int] = list(range(n_blocks - 1, -1, -1))
        self.lru: "OrderedDict[int, None]" = OrderedDict()  # refcount-0, hashed
        self.tables: List[List[int]] = [[] for _ in range(max_slots)]
        # content addressing (hashed = immutable full prefill blocks only)
        self.by_hash: Dict[str, int] = {}
        self.hash_of: Dict[int, str] = {}
        self.parent_of: Dict[int, str] = {}
        self.tokens_of: Dict[int, Tuple[int, ...]] = {}
        # counters for the engine's metrics feed
        self.hits = 0            # admissions that reused >= 1 block
        self.hit_tokens = 0      # prompt tokens served from cache
        self.misses = 0
        self.cow_copies = 0

    # ------------------------------------------------------------- hashing --
    def chunk_digests(self, tokens) -> List[Tuple[str, Tuple[int, ...]]]:
        """(chain digest, chunk tokens) for every FULL block of ``tokens``."""
        bt = self.geom.block_tokens
        toks = [int(t) for t in tokens]
        out, parent = [], ROOT_DIGEST
        for i in range(len(toks) // bt):
            chunk = tuple(toks[i * bt:(i + 1) * bt])
            parent = _chain(parent, chunk)
            out.append((parent, chunk))
        return out

    def match_prefix(self, tokens) -> PrefixMatch:
        """Longest cached chain of full blocks covering the prompt head.

        Pure lookup — no refcounts move until :meth:`claim_blocks` (so a
        caller that cannot admit after all leaves the pool untouched).
        """
        bids: List[int] = []
        parent = ROOT_DIGEST
        for digest, _chunk in self.chunk_digests(tokens):
            bid = self.by_hash.get(digest)
            if bid is None:
                break
            bids.append(bid)
            parent = digest
        return PrefixMatch(bids=bids,
                           n_tokens=len(bids) * self.geom.block_tokens,
                           tail_digest=parent)

    # ---------------------------------------------------------- allocation --
    def available(self) -> int:
        """Blocks allocatable right now (free + evictable cached)."""
        return len(self.free) + len(self.lru)

    def alloc(self) -> int:
        """One writable block: free list first, then the LRU cached block
        (its hash entries are unregistered — the prefix it cached is gone)."""
        if self.free:
            bid = self.free.pop()
        elif self.lru:
            bid, _ = self.lru.popitem(last=False)       # least recently used
            self._unregister(bid)
        else:
            raise PoolExhausted(
                f"pool of {self.n_blocks} blocks exhausted "
                f"({int((self.refcount > 0).sum())} live)")
        self.refcount[bid] = 1
        return bid

    def claim_blocks(self, bids: List[int]) -> None:
        """Take a reference on cached blocks (prefix hit): refcount-0 blocks
        leave the LRU, everything else just bumps."""
        for bid in bids:
            if self.refcount[bid] == 0:
                self.lru.pop(bid, None)
            self.refcount[bid] += 1

    def _unregister(self, bid: int) -> None:
        digest = self.hash_of.pop(bid, None)
        if digest is not None and self.by_hash.get(digest) == bid:
            del self.by_hash[digest]
        self.parent_of.pop(bid, None)
        self.tokens_of.pop(bid, None)

    def release(self, bid: int) -> None:
        self.refcount[bid] -= 1
        if self.refcount[bid] < 0:
            raise AssertionError(f"block {bid} refcount underflow")
        if self.refcount[bid] == 0:
            if bid in self.hash_of:
                self.lru[bid] = None        # retained: future prefix hits
                self.lru.move_to_end(bid)
            else:
                self.free.append(bid)

    # ------------------------------------------------------- content index --
    def register_full_block(self, bid: int, digest: str, parent: str,
                            tokens: Tuple[int, ...]) -> None:
        """Publish a full prefill-written block for prefix reuse.

        First writer wins: if ``digest`` is already registered (two
        identical prompts admitted back to back), the newcomer stays
        private rather than stealing the address — both spellings decode
        identically, the duplicate just isn't shared onward.
        """
        if len(tokens) != self.geom.block_tokens:
            raise ValueError(
                f"only full blocks are content-addressed "
                f"({len(tokens)} != {self.geom.block_tokens} tokens)")
        if digest in self.by_hash:
            return
        self.by_hash[digest] = bid
        self.hash_of[bid] = digest
        self.parent_of[bid] = parent
        self.tokens_of[bid] = tuple(int(t) for t in tokens)

    # --------------------------------------------------------- slot tables --
    def begin_slot(self, slot: int, bids: List[int]) -> None:
        if self.tables[slot]:
            raise AssertionError(f"slot {slot} table not released")
        self.tables[slot] = list(bids)

    def append_block(self, slot: int) -> int:
        bid = self.alloc()
        self.tables[slot].append(bid)
        return bid

    def release_slot(self, slot: int) -> List[int]:
        """Drop the slot's references; returns the released block ids."""
        bids, self.tables[slot] = self.tables[slot], []
        for bid in bids:
            self.release(bid)
        return bids

    def fork_slot(self, src: int, dst: int) -> None:
        """Alias every block of ``src`` into ``dst`` (COW fork: refcounts
        bump, nothing is copied until one side writes)."""
        if self.tables[dst]:
            raise AssertionError(f"fork target slot {dst} not free")
        self.tables[dst] = list(self.tables[src])
        self.claim_blocks(self.tables[dst])

    def ensure_writable(self, slot: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write guard before appending into the slot's tail block.

        Shared tail (refcount > 1, or content-addressed — published blocks
        are immutable even at refcount 1, a future prefix hit must see the
        bytes the hash promised) -> allocate a private block, swap it into
        the table, drop one reference on the original, and return
        ``(src, dst)`` so the caller can issue the device copy.  Returns
        None when the tail is already private.
        """
        if not self.tables[slot]:
            return None
        src = self.tables[slot][-1]
        if self.refcount[src] <= 1 and src not in self.hash_of:
            return None
        dst = self.alloc()
        self.tables[slot][-1] = dst
        self.release(src)
        self.cow_copies += 1
        return src, dst

    def private_bids(self, slot: int) -> List[int]:
        """The slot's exclusively-owned, unpublished blocks (safe to scrub:
        zeroing them cannot corrupt another slot or a cached prefix)."""
        return [b for b in self.tables[slot]
                if self.refcount[b] == 1 and b not in self.hash_of]

    def device_table(self, width: int) -> np.ndarray:
        """(max_slots, width) int32 block table, sentinel-padded."""
        out = np.full((self.max_slots, width), self.sentinel, np.int32)
        for s, tab in enumerate(self.tables):
            if len(tab) > width:
                raise AssertionError(
                    f"slot {s} holds {len(tab)} blocks > table width {width}")
            out[s, :len(tab)] = tab
        return out

    # ------------------------------------------------------------ snapshot --
    def snapshot_meta(self) -> dict:
        """JSON-able state; together with the device pools this is the whole
        cache (ft/serving.py carries it inside the engine snapshot)."""
        return {
            "geometry": self.geom.describe(),
            "n_blocks": self.n_blocks,
            "refcount": self.refcount.tolist(),
            "free": list(self.free),
            "lru": list(self.lru.keys()),
            "tables": [list(t) for t in self.tables],
            "hashed": [
                {"bid": bid, "digest": d, "parent": self.parent_of[bid],
                 "tokens": list(self.tokens_of[bid])}
                for bid, d in sorted(self.hash_of.items())],
            "hits": self.hits, "hit_tokens": self.hit_tokens,
            "misses": self.misses, "cow_copies": self.cow_copies,
        }

    def restore_meta(self, meta: dict) -> None:
        if meta["geometry"] != self.geom.describe():
            raise ValueError(
                f"snapshot page geometry {meta['geometry']} does not match "
                f"this engine's {self.geom.describe()}")
        if meta["n_blocks"] != self.n_blocks:
            raise ValueError(
                f"snapshot pool has {meta['n_blocks']} blocks, engine has "
                f"{self.n_blocks}")
        self.refcount = np.asarray(meta["refcount"], np.int32)
        self.free = list(meta["free"])
        self.lru = OrderedDict((int(b), None) for b in meta["lru"])
        self.tables = [list(map(int, t)) for t in meta["tables"]]
        self.by_hash, self.hash_of = {}, {}
        self.parent_of, self.tokens_of = {}, {}
        for h in meta["hashed"]:
            bid = int(h["bid"])
            self.by_hash[h["digest"]] = bid
            self.hash_of[bid] = h["digest"]
            self.parent_of[bid] = h["parent"]
            self.tokens_of[bid] = tuple(int(t) for t in h["tokens"])
        self.hits = int(meta.get("hits", 0))
        self.hit_tokens = int(meta.get("hit_tokens", 0))
        self.misses = int(meta.get("misses", 0))
        self.cow_copies = int(meta.get("cow_copies", 0))
        self.check_invariants()

    # ----------------------------------------------------------- integrity --
    def stats(self) -> dict:
        live = int((self.refcount > 0).sum())
        return {"blocks": self.n_blocks, "live": live,
                "free": len(self.free), "cached": len(self.lru),
                "hits": self.hits, "misses": self.misses,
                "hit_tokens": self.hit_tokens, "cow_copies": self.cow_copies,
                "block_tokens": self.geom.block_tokens}

    def check_invariants(self) -> None:
        """Every block is in exactly one of {free, lru, live}; refcounts
        equal table references; hash index is bijective."""
        refs = np.zeros((self.n_blocks,), np.int32)
        for tab in self.tables:
            for bid in tab:
                refs[bid] += 1
        if not np.array_equal(refs, self.refcount):
            bad = np.nonzero(refs != self.refcount)[0][:8]
            raise AssertionError(
                f"refcount mismatch at blocks {bad.tolist()}: "
                f"tables say {refs[bad].tolist()}, "
                f"counts say {self.refcount[bad].tolist()}")
        free_set, lru_set = set(self.free), set(self.lru)
        if len(free_set) != len(self.free):
            raise AssertionError("duplicate block on the free list")
        if free_set & lru_set:
            raise AssertionError(f"blocks both free and cached: "
                                 f"{sorted(free_set & lru_set)[:8]}")
        live_set = set(np.nonzero(self.refcount > 0)[0].tolist())
        if live_set & (free_set | lru_set):
            raise AssertionError("live block on a reuse list")
        union = free_set | lru_set | live_set
        if union != set(range(self.n_blocks)):
            raise AssertionError(
                f"leaked blocks: {sorted(set(range(self.n_blocks)) - union)[:8]}")
        for bid in lru_set:
            if bid not in self.hash_of:
                raise AssertionError(f"unhashed block {bid} in LRU")
        for digest, bid in self.by_hash.items():
            if self.hash_of.get(bid) != digest:
                raise AssertionError(f"hash index out of sync at block {bid}")

    # convenience used by tests
    def seen_digests(self) -> Set[str]:
        return set(self.by_hash)
