"""Vectorized, bit-exact posit<->IEEE-754 codec in JAX.

This is the TPU-side analogue of the paper's FPU-boundary codecs (Fig. 2(b)):
``posit_decode`` is the input decoder (posit -> FP), ``posit_encode`` the output
encoder (FP -> posit). Both are pure element-wise integer pipelines, callable
from regular jitted code *and* from inside Pallas kernel bodies (they only use
jnp/lax ops on arrays).

Dynamic exponent size: ``es`` may be a Python int (static) or a traced int32
scalar (dynamic, the paper's ``pes`` CSR field) — one compiled executable then
serves every es value, mirroring the hardware's runtime configurability. All
shift amounts are constructed to stay in [0, 31] for any es in [0, 3] and any
input bit pattern, so no lane ever hits an undefined shift.

Bit-exactness contract: validated exhaustively against ``ref_codec`` (all 256
p8 codes x es in {0..3}; all 65536 p16 codes x es in {0,1,2,3}).
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.types import PositFmt

EsLike = Union[int, jax.Array]

_U32 = jnp.uint32
_NAN_BITS = 0x7FC00000  # plain int: jnp constants at module scope would be
                        # captured as consts by Pallas kernel traces


def _u32(x) -> jax.Array:
    return jnp.asarray(x, dtype=_U32)


def _es_u32(es: EsLike) -> jax.Array:
    """Normalize es to a clamped uint32 scalar (0..3)."""
    e = jnp.asarray(es, dtype=jnp.int32)
    return jnp.clip(e, 0, 3).astype(_U32)


def _floor_log2_small(w: jax.Array) -> jax.Array:
    """floor(log2(w)) for int32 w in [1, 2^24): exact via the f32 exponent field.

    Used instead of lax.clz so the same codec source lowers both through XLA and
    through Mosaic inside Pallas kernel bodies (clz is not in the Mosaic op set;
    int->f32 convert + bitcast are). Conversion is exact below 2^24, so the
    exponent field is the exact floor-log2.
    """
    f = w.astype(jnp.float32)
    return (lax.bitcast_convert_type(f, jnp.int32) >> 23) - 127


# =====================================================================
# decode: posit bits -> float32 (exact)
# =====================================================================

def posit_decode(codes: jax.Array, nbits: int, es: EsLike) -> jax.Array:
    """Decode n-bit posit codes (uint8/uint16/int) to float32, exactly.

    NaR (0b10..0) decodes to NaN; 0 to +0.0.
    """
    assert nbits in (8, 16), nbits
    n = nbits
    esl = _es_u32(es)
    c = codes.astype(_U32) & _u32((1 << n) - 1)

    sign = (c >> _u32(n - 1)) & _u32(1)
    neg = sign == 1
    absc = jnp.where(neg, (_u32(1 << n) - c) & _u32((1 << n) - 1), c)

    r0 = (absc >> _u32(n - 2)) & _u32(1)
    # Locate the regime terminator: flip the run to zeros, find the highest set
    # bit. w < 2^15, so the f32-exponent floor-log2 is exact (Mosaic-safe).
    w = jnp.where(r0 == 1, (~absc) & _u32((1 << (n - 1)) - 1), absc)
    p = _floor_log2_small(jnp.maximum(w, 1).astype(jnp.int32))
    m = jnp.where(w == 0, n - 1, (n - 2) - p)  # regime run length
    k = jnp.where(r0 == 1, m - 1, -m)  # int32

    # Left-align the n-1 body bits at bit 31 (sign excluded), then shift out the
    # regime run + terminator; remaining [exp|frac] left-aligned.
    y = absc << _u32(33 - n)
    rem = y << _u32(m + 1)  # m+1 <= n <= 16
    # exponent: top `es` bits of rem via an 8-bit window (avoids shift-by-32)
    e = ((rem >> _u32(24)) >> (_u32(8) - esl)).astype(jnp.int32)
    frac_la = rem << esl  # fraction bits, left-aligned at bit 31
    mant23 = frac_la >> _u32(9)

    scale = k * (jnp.int32(1) << esl.astype(jnp.int32)) + e  # |scale| <= 112
    fbits = (
        (sign << _u32(31))
        | ((scale + 127).astype(_U32) << _u32(23))
        | mant23
    )
    out = lax.bitcast_convert_type(fbits, jnp.float32)

    is_zero = c == 0
    is_nar = c == _u32(1 << (n - 1))
    nan = lax.bitcast_convert_type(jnp.full(c.shape, _NAN_BITS, dtype=_U32), jnp.float32)
    return jnp.where(is_zero, 0.0, jnp.where(is_nar, nan, out))


def posit_decode_to(codes: jax.Array, nbits: int, es: EsLike, dtype) -> jax.Array:
    """Decode then cast. For p8 the cast to bfloat16 is exact (DESIGN.md §2)."""
    return posit_decode(codes, nbits, es).astype(dtype)


# =====================================================================
# field decode: posit bits -> integer (sign, scale, significand) fields
# =====================================================================

def _sigw(nbits: int) -> int:
    """Significand width incl. hidden bit: 6 for p8, 14 for p16 (max fraction
    bits at es=0 plus the hidden bit)."""
    return 6 if nbits == 8 else 14


def _decode_fields(codes: jax.Array, nbits: int, esl: jax.Array):
    """posit bits -> (neg, scale:int32, sig:uint32 hidden@SIGW-1, is_zero, is_nar).

    The integer-domain front half of the codec, shared by the true-posit ALU
    (repro.core.alu) and the quire (repro.core.quire). Uses the same
    f32-exponent floor-log2 trick as ``posit_decode`` so it lowers through both
    XLA and Mosaic (Pallas kernel bodies). Fields for zero/NaR inputs are
    garbage and must be masked via the returned flags.
    """
    n = nbits
    c = codes.astype(_U32) & _u32((1 << n) - 1)
    is_zero = c == 0
    is_nar = c == _u32(1 << (n - 1))
    neg = ((c >> _u32(n - 1)) & 1) == 1
    absc = jnp.where(neg, (_u32(1 << n) - c) & _u32((1 << n) - 1), c)
    r0 = (absc >> _u32(n - 2)) & _u32(1)
    w = jnp.where(r0 == 1, (~absc) & _u32((1 << (n - 1)) - 1), absc)
    p = _floor_log2_small(jnp.maximum(w, 1).astype(jnp.int32))
    m = jnp.where(w == 0, n - 1, (n - 2) - p)  # regime run length
    k = jnp.where(r0 == 1, m - 1, -m)
    y = absc << _u32(33 - n)
    rem = y << _u32(m + 1)
    e = ((rem >> _u32(24)) >> (_u32(8) - esl)).astype(jnp.int32)
    frac_la = rem << esl
    scale = k * (jnp.int32(1) << esl.astype(jnp.int32)) + e
    sigw = _sigw(n)
    sig = (_u32(1) << _u32(sigw - 1)) | (frac_la >> _u32(32 - (sigw - 1)))
    return neg, scale, sig, is_zero, is_nar


# =====================================================================
# encode core: (sign, scale, fraction, sticky) -> posit bits
# =====================================================================

def _encode_fields(
    neg: jax.Array,       # bool — sign of the value
    scale: jax.Array,     # int32 — floor(log2 |x|) (raw; clamped here)
    frac_la: jax.Array,   # uint32 — fraction bits (no hidden bit), MSB at bit 31
    sticky: jax.Array,    # bool — true if bits were lost before this point
    nbits: int,
    esl: jax.Array,       # uint32 scalar in [0,3]
) -> jax.Array:
    """Assemble + round an n-bit posit from sign/scale/fraction fields.

    Rounding is RNE on the encoding: the increment is added to the integer body
    so mantissa->exponent->regime carries propagate exactly as in hardware.
    Saturation: scale >= smax -> maxpos; scale < -smax -> minpos (never 0/NaR).
    """
    n = nbits
    es_i = esl.astype(jnp.int32)
    smax = jnp.int32(n - 2) << es_i
    sat_hi = scale >= smax
    sat_lo = scale < -smax
    scale_c = jnp.clip(scale, -smax, smax - 1)

    k = lax.shift_right_arithmetic(scale_c, es_i)  # floor(scale / 2^es)
    e = (scale_c - (k << es_i)).astype(_U32)       # 0 .. 2^es-1  (<= 7)
    kp = jnp.maximum(k, 0).astype(_U32)
    reg = jnp.where(k >= 0, ((_u32(1) << (kp + 1)) - 1) << 1, _u32(1))
    r_len = jnp.where(k >= 0, k + 2, 1 - k)
    t = (jnp.int32(n - 1) - r_len).astype(_U32)    # 0 .. n-3  (<= 13)

    # [exp | frac] left-aligned at bit 31. e has `es` bits: e_la = e * 2^(32-es).
    e_la = (e << 29) << (_u32(3) - esl)
    lost = frac_la & ((_u32(1) << esl) - 1)
    u_la = e_la | (frac_la >> esl)

    tail = (u_la >> 16) >> (_u32(16) - t)
    g_rest = u_la << t
    g = g_rest >> 31
    st = sticky | (lost != 0) | ((g_rest << 1) != 0)

    body = (reg << t) | tail
    inc = (g == 1) & (st | ((body & 1) == 1))
    body = body + inc.astype(_U32)
    body = jnp.minimum(body, _u32((1 << (n - 1)) - 1))
    body = jnp.where(sat_hi, _u32((1 << (n - 1)) - 1), jnp.where(sat_lo, _u32(1), body))

    code = jnp.where(neg, _u32(1 << n) - body, body) & _u32((1 << n) - 1)
    return code


def posit_encode(x: jax.Array, nbits: int, es: EsLike,
                 ftz: bool = False) -> jax.Array:
    """Encode float32 values to n-bit posit codes (RNE + posit saturation).

    NaN/Inf -> NaR; +-0 -> 0; 0<|x|<minpos -> +-minpos; |x|>maxpos -> +-maxpos.
    Returns uint8 (n=8) or uint16 (n=16).

    ftz=True (beyond-paper, used by gradient compression): values with
    |x| <= minpos/2 round to 0 instead of saturating up to minpos — plain RNE
    against {0} U posits. The standard's never-to-zero rule preserves
    "x != 0 stays != 0", but for compressed *sums* it injects +-minpos noise on
    every near-zero element; FTZ removes that bias (EXPERIMENTS.md §Perf).
    """
    assert nbits in (8, 16), nbits
    n = nbits
    esl = _es_u32(es)
    xf = x.astype(jnp.float32)
    bits = lax.bitcast_convert_type(xf, _U32)

    neg = (bits >> 31) == 1
    a_bits = bits & _u32(0x7FFFFFFF)
    is_zero = a_bits == 0
    is_nar = a_bits >= _u32(0x7F800000)

    scale = (a_bits >> 23).astype(jnp.int32) - 127     # subnormals -> -127 -> sat_lo
    frac_la = (a_bits & _u32(0x7FFFFF)) << 9           # 23 frac bits at the top
    sticky = jnp.zeros(bits.shape, dtype=bool)

    code = _encode_fields(neg, scale, frac_la, sticky, n, esl)
    if ftz:
        smax = jnp.int32(n - 2) << esl.astype(jnp.int32)
        # |x| <= minpos/2 == 2^-(smax+1): below it, or exactly it (tie -> even=0)
        below = scale < -(smax + 1)
        at_half = (scale == -(smax + 1)) & (frac_la == 0)
        code = jnp.where(below | at_half, _u32(0), code)
    code = jnp.where(is_zero, _u32(0), code)
    code = jnp.where(is_nar, _u32(1 << (n - 1)), code)
    return code.astype(jnp.uint8 if n == 8 else jnp.uint16)


def auto_es(x: jax.Array, nbits: int, margin: int = 4) -> jax.Array:
    """Runtime exponent-size selection (the paper's dynamic-es feature, used
    as a *policy*): the smallest es in [0,3] whose regime range covers the
    tensor's magnitude, plus `margin` octaves of headroom below the max.

    Small es maximizes fraction bits near the mode; the returned scalar is
    traced, so one executable serves every tensor scale (e.g. gradient
    compression across layers with wildly different magnitudes).
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    # exponent of the largest value (clamped; 0 if the tensor is all zeros)
    e = jnp.where(amax > 0,
                  jnp.abs(jnp.floor(jnp.log2(jnp.maximum(amax, 1e-38)))), 0.0)
    need = e + margin  # cover max plus headroom for the distribution body
    es = jnp.ceil(jnp.log2(jnp.maximum(need / (nbits - 2), 1.0)))
    return jnp.clip(es.astype(jnp.int32), 0, 3)


# =====================================================================
# format-descriptor convenience wrappers
# =====================================================================

def decode(codes: jax.Array, fmt: PositFmt, es: EsLike | None = None) -> jax.Array:
    return posit_decode(codes, fmt.nbits, fmt.es if es is None else es)


def encode(x: jax.Array, fmt: PositFmt, es: EsLike | None = None) -> jax.Array:
    return posit_encode(x, fmt.nbits, fmt.es if es is None else es)


def quantize(x: jax.Array, fmt: PositFmt, es: EsLike | None = None) -> jax.Array:
    """Round-trip x through the posit format (value-level quantization)."""
    e = fmt.es if es is None else es
    return posit_decode(posit_encode(x, fmt.nbits, e), fmt.nbits, e).astype(x.dtype)
