"""Fault-tolerant checkpointing: atomic, sharded, async, posit-compressible,
elastic (any saved topology -> any restore topology).

Layout (one directory per step):
    ckpt_dir/step_000123.tmp/      while writing
        manifest.json              tree structure, shapes, dtypes, offsets,
                                   per-leaf crc32, format, step
        shard_00000.bin            flat leaves, raw C-contiguous bytes
    ckpt_dir/step_000123/          after atomic rename (os.replace)

The shard is raw bytes rather than npz on purpose: the serving plane
snapshots a live engine from a background thread, and ``np.savez`` streams
through ``zipfile`` in small Python-level chunks that hold the GIL in
multi-ms bursts — measurable decode stalls.  A raw shard is one GIL-releasing
``write`` per leaf; integrity comes from a single-shot ``zlib.crc32`` per
leaf (also GIL-releasing for large buffers) recorded in the manifest and
verified on load.  Old npz shards remain readable.

Durability contract: a checkpoint is valid iff the final directory exists with
a readable manifest — a crash mid-write leaves only a .tmp that restart-scan
ignores (and garbage-collects). ``CheckpointManager`` adds async saves (a
worker thread snapshots device arrays to host first), keep-last-k retention,
and deterministic data-cursor restore.

Posit-compressed checkpoints (policy.checkpoint): float leaves are stored as
P(16,es) codes + the manifest records the format — 2x smaller at-rest, decode
on load. Exact-dtype leaves (ints, already-posit params) are stored raw.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import sys
import threading
import time
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import posit_decode, posit_encode
from repro.core.types import PositFmt, get_format

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
             for kp, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    fmt: Optional[PositFmt] = None,
                    extra: Optional[dict] = None) -> str:
    """Blocking atomic save. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    paths, leaves, _ = _flatten_with_paths(tree)
    meta, off = [], 0
    with open(os.path.join(tmp, "shard_00000.bin"), "wb") as f:
        for p, leaf in zip(paths, leaves):
            arr = np.asarray(leaf)
            entry = {"path": p, "dtype": str(arr.dtype),
                     "shape": list(arr.shape), "codec": "raw"}
            if fmt is not None and arr.dtype in (np.float32, np.float64):
                arr = np.asarray(posit_encode(
                    jnp.asarray(arr, jnp.float32), fmt.nbits, fmt.es))
                entry["codec"] = fmt.name
            # reshape(-1): a 0-d memoryview cannot cast to bytes
            buf = memoryview(np.ascontiguousarray(arr).reshape(-1)).cast("B")
            entry["stored_dtype"] = str(arr.dtype)
            entry["offset"], entry["nbytes"] = off, buf.nbytes
            entry["crc32"] = zlib.crc32(buf)
            f.write(buf)
            off += buf.nbytes
            meta.append(entry)
        f.flush()
        os.fsync(f.fileno())
    manifest = {"step": step, "leaves": meta, "extra": extra or {}}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic on POSIX
    # fsync the parent directory so the rename itself is durable — without
    # it a power cut can leave a manifest-complete directory that the
    # filesystem forgets (the durability contract says a visible final dir
    # IS a valid checkpoint, so its visibility must be on disk too)
    dir_fd = os.open(ckpt_dir, os.O_RDONLY)
    try:
        os.fsync(dir_fd)
    finally:
        os.close(dir_fd)
    return final


def load_checkpoint(ckpt_dir: str, tree_like: Any, *, step: Optional[int] = None,
                    shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of `tree_like`; elastic re-sharding applied
    via `shardings` (a matching pytree of NamedSharding or None)."""
    step_dir = (os.path.join(ckpt_dir, f"step_{step:08d}") if step is not None
                else latest_checkpoint(ckpt_dir))
    if step_dir is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    with open(os.path.join(step_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    bin_path = os.path.join(step_dir, "shard_00000.bin")
    if os.path.exists(bin_path):
        with open(bin_path, "rb") as f:
            blob = memoryview(f.read())
        data = None
    else:  # pre-raw-shard checkpoint (npz layout)
        blob = None
        data = np.load(os.path.join(step_dir, "shard_00000.npz"))

    paths, leaves, treedef = _flatten_with_paths(tree_like)
    by_path = {e["path"]: (i, e) for i, e in enumerate(manifest["leaves"])}
    out = []
    flat_sh = (treedef.flatten_up_to(shardings) if shardings is not None
               else [None] * len(leaves))
    for p, like, sh in zip(paths, leaves, flat_sh):
        i, entry = by_path[p]
        if blob is not None:
            raw = blob[entry["offset"]:entry["offset"] + entry["nbytes"]]
            if zlib.crc32(raw) != entry["crc32"]:
                # deliberately NOT OSError: corruption is permanent, the
                # with_retries(retryable=(OSError,)) wrapper must not spin
                raise ValueError(
                    f"checkpoint shard corrupt: leaf {p} in {step_dir}")
            # posit_encode preserves shape, so entry["shape"] is right for
            # both raw and posit-coded leaves
            arr = np.frombuffer(
                raw, dtype=np.dtype(entry["stored_dtype"])).reshape(
                    entry["shape"])
        else:
            arr = data[f"a{i}"]
        if entry["codec"] != "raw":
            f = get_format(entry["codec"])
            arr = np.asarray(posit_decode(jnp.asarray(arr), f.nbits, f.es))
        arr = arr.astype(like.dtype).reshape(like.shape)
        out.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
    return treedef.unflatten(out), manifest


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, _MANIFEST)))
    return os.path.join(ckpt_dir, steps[-1]) if steps else None


def gc_tmp(ckpt_dir: str) -> int:
    """Remove crash leftovers (.tmp dirs). Returns count removed."""
    n = 0
    if os.path.isdir(ckpt_dir):
        for d in os.listdir(ckpt_dir):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(ckpt_dir, d))
                n += 1
    return n


class CheckpointManager:
    """Async save + retention + auto-resume.

    Failure surfacing: a background save failure is raised on the next
    ``save_async()``/``wait()``/``close()`` *and* surfaced promptly — a line
    on stderr plus, when ``metrics`` (a ``repro.obs.MetricsRegistry``) is
    given, the ``ckpt_save_errors`` counter and ``ckpt_last_saved_step``
    gauge move immediately (an operator dashboard sees the failure before
    the next checkpoint cadence does).  Transient IO errors are retried with
    decorrelated-jitter backoff (``ft.with_retries``) before counting as a
    failure; ``pre_save`` is a fault-injection hook (``FaultPlan`` in
    ``repro.ft.serving``) called before every save attempt.
    """

    def __init__(self, ckpt_dir: str, *, keep: int = 3,
                 fmt: Optional[PositFmt] = None, metrics=None,
                 retries: int = 2, retry_base_delay: float = 0.05,
                 pre_save=None):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.fmt = fmt
        self.retries = retries
        self.retry_base_delay = retry_base_delay
        self.pre_save = pre_save
        self._err: Optional[BaseException] = None
        # reap orphaned .tmp dirs from a crashed predecessor BEFORE the
        # worker starts writing new ones (a crash mid-save leaves only .tmp)
        self.gc_tmp_reaped = gc_tmp(ckpt_dir)
        self._m_errors = self._m_saves = self._m_retries = None
        self._m_last_step = self._m_save_s = None
        if metrics is not None:
            self._m_saves = metrics.counter(
                "ckpt_saves", "checkpoints committed")
            self._m_errors = metrics.counter(
                "ckpt_save_errors", "checkpoint saves that failed for good")
            self._m_retries = metrics.counter(
                "ckpt_save_retries", "transient save failures retried")
            self._m_last_step = metrics.gauge(
                "ckpt_last_saved_step", "step of the newest durable snapshot")
            self._m_save_s = metrics.histogram(
                "ckpt_save_s", "wall time of one background save")
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def _save_once(self, step, host_tree, extra):
        if self.pre_save is not None:
            self.pre_save(step)
        # the raw-shard writes and crc32 release the GIL, but the remaining
        # Python in a save (manifest json, retention rmtree, posit encode
        # dispatch) would hold it in bursts up to the default 5ms switch
        # interval — a serving thread's decode dispatch stalls by that much
        # per burst.  Shrink the interval for the duration of the save so
        # the background writer yields every ~0.5ms instead; the writer is
        # background, the server is not.
        old = sys.getswitchinterval()
        sys.setswitchinterval(5e-4)
        try:
            return save_checkpoint(self.ckpt_dir, step, host_tree,
                                   fmt=self.fmt, extra=extra)
        finally:
            sys.setswitchinterval(old)

    def _run(self):
        from repro.ft.runtime import with_retries  # late: avoids import cycle

        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host_tree, extra = item
            try:
                t0 = time.perf_counter()
                with_retries(
                    lambda: self._save_once(step, host_tree, extra),
                    retries=self.retries,
                    base_delay=self.retry_base_delay,
                    retryable=(OSError, RuntimeError),
                    on_retry=lambda n, e: (
                        self._m_retries.inc()
                        if self._m_retries is not None else None))
                if self._m_saves is not None:
                    self._m_saves.inc()
                    self._m_last_step.set(step)
                    self._m_save_s.observe(time.perf_counter() - t0)
                self._retain()
            except BaseException as e:  # re-raised on next save()/wait()
                self._err = e
                if self._m_errors is not None:
                    self._m_errors.inc()
                print(f"checkpoint save (step {step}) failed: {e!r}",
                      file=sys.stderr)
            finally:
                self._q.task_done()

    def _retain(self):
        steps = sorted(
            d for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, d))

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        if self._err:
            raise RuntimeError("async checkpoint failed") from self._err
        # snapshot to host memory NOW so training can mutate device buffers
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree, extra))

    def wait(self):
        """Block until every queued save has committed."""
        self._q.join()
        if self._err:
            raise RuntimeError("async checkpoint failed") from self._err

    def close(self):
        self._q.put(None)
        self._worker.join(timeout=60)
        if self._err:
            raise RuntimeError("async checkpoint failed") from self._err

    def restore_or_none(self, tree_like: Any, shardings: Any = None):
        from repro.ft.runtime import with_retries  # late: avoids import cycle

        if latest_checkpoint(self.ckpt_dir) is None:
            return None
        return with_retries(
            lambda: load_checkpoint(self.ckpt_dir, tree_like,
                                    shardings=shardings),
            retries=self.retries, base_delay=self.retry_base_delay,
            retryable=(OSError,))
