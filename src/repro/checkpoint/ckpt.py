"""Fault-tolerant checkpointing: atomic, sharded, async, posit-compressible,
elastic (any saved topology -> any restore topology).

Layout (one directory per step):
    ckpt_dir/step_000123.tmp/      while writing
        manifest.json              tree structure, shapes, dtypes, format, step
        shard_00000.npz            flat leaves (host-sharded on multi-host)
    ckpt_dir/step_000123/          after atomic rename (os.replace)

Durability contract: a checkpoint is valid iff the final directory exists with
a readable manifest — a crash mid-write leaves only a .tmp that restart-scan
ignores (and garbage-collects). ``CheckpointManager`` adds async saves (a
worker thread snapshots device arrays to host first), keep-last-k retention,
and deterministic data-cursor restore.

Posit-compressed checkpoints (policy.checkpoint): float leaves are stored as
P(16,es) codes + the manifest records the format — 2x smaller at-rest, decode
on load. Exact-dtype leaves (ints, already-posit params) are stored raw.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import posit_decode, posit_encode
from repro.core.types import PositFmt, get_format

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
             for kp, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    fmt: Optional[PositFmt] = None,
                    extra: Optional[dict] = None) -> str:
    """Blocking atomic save. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    paths, leaves, _ = _flatten_with_paths(tree)
    arrays, meta = {}, []
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        entry = {"path": p, "dtype": str(arr.dtype), "shape": list(arr.shape),
                 "codec": "raw"}
        if fmt is not None and arr.dtype in (np.float32, np.float64):
            codes = np.asarray(posit_encode(
                jnp.asarray(arr, jnp.float32), fmt.nbits, fmt.es))
            arrays[f"a{i}"] = codes
            entry["codec"] = fmt.name
        else:
            arrays[f"a{i}"] = arr
        meta.append(entry)

    np.savez(os.path.join(tmp, "shard_00000.npz"), **arrays)
    manifest = {"step": step, "leaves": meta, "extra": extra or {}}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic on POSIX
    return final


def load_checkpoint(ckpt_dir: str, tree_like: Any, *, step: Optional[int] = None,
                    shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of `tree_like`; elastic re-sharding applied
    via `shardings` (a matching pytree of NamedSharding or None)."""
    step_dir = (os.path.join(ckpt_dir, f"step_{step:08d}") if step is not None
                else latest_checkpoint(ckpt_dir))
    if step_dir is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    with open(os.path.join(step_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(step_dir, "shard_00000.npz"))

    paths, leaves, treedef = _flatten_with_paths(tree_like)
    by_path = {e["path"]: (i, e) for i, e in enumerate(manifest["leaves"])}
    out = []
    flat_sh = (treedef.flatten_up_to(shardings) if shardings is not None
               else [None] * len(leaves))
    for p, like, sh in zip(paths, leaves, flat_sh):
        i, entry = by_path[p]
        arr = data[f"a{i}"]
        if entry["codec"] != "raw":
            f = get_format(entry["codec"])
            arr = np.asarray(posit_decode(jnp.asarray(arr), f.nbits, f.es))
        arr = arr.astype(like.dtype).reshape(like.shape)
        out.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
    return treedef.unflatten(out), manifest


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, _MANIFEST)))
    return os.path.join(ckpt_dir, steps[-1]) if steps else None


def gc_tmp(ckpt_dir: str) -> int:
    """Remove crash leftovers (.tmp dirs). Returns count removed."""
    n = 0
    if os.path.isdir(ckpt_dir):
        for d in os.listdir(ckpt_dir):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(ckpt_dir, d))
                n += 1
    return n


class CheckpointManager:
    """Async save + retention + auto-resume."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3,
                 fmt: Optional[PositFmt] = None):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.fmt = fmt
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        gc_tmp(ckpt_dir)

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host_tree, extra = item
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree,
                                fmt=self.fmt, extra=extra)
                self._retain()
            except BaseException as e:  # surfaced on next save()/close()
                self._err = e
            finally:
                self._q.task_done()

    def _retain(self):
        steps = sorted(
            d for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, d))

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        if self._err:
            raise RuntimeError("async checkpoint failed") from self._err
        # snapshot to host memory NOW so training can mutate device buffers
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree, extra))

    def wait(self):
        """Block until every queued save has committed."""
        self._q.join()
        if self._err:
            raise RuntimeError("async checkpoint failed") from self._err

    def close(self):
        self._q.put(None)
        self._worker.join(timeout=60)
        if self._err:
            raise RuntimeError("async checkpoint failed") from self._err

    def restore_or_none(self, tree_like: Any, shardings: Any = None):
        if latest_checkpoint(self.ckpt_dir) is None:
            return None
        return load_checkpoint(self.ckpt_dir, tree_like, shardings=shardings)
