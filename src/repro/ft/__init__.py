from repro.ft.runtime import (  # noqa: F401
    FaultTolerantLoop, PreemptionSignal, StragglerMonitor, with_retries,
)
