from repro.ft.runtime import (  # noqa: F401
    FaultTolerantLoop, PreemptionSignal, StragglerMonitor, with_retries,
)
from repro.ft.serving import (  # noqa: F401
    DegradationController, EngineSnapshotter, FaultPlan, next_rung,
)
