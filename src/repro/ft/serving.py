"""Fault-tolerant serving plane (DESIGN.md §13).

Three components wired into ``ContinuousBatchingEngine``:

* :class:`EngineSnapshotter` — cadenced crash-safe snapshots of the *full*
  engine state (ragged posit KV cache, slot grid, sampler RNG key, emitted-
  token buffers, pending queue) through ``CheckpointManager``'s async worker.
  A killed process restores via :meth:`EngineSnapshotter.restore_into` and
  every in-flight stream continues **bit-identically**: the snapshot stores
  raw posit code arrays (never re-encoded — ``fmt=None``) plus the PRNG key
  data, and the engine restores into the same compiled executables.
* :class:`FaultPlan` — deterministic chaos: stall a decode step (exercises
  ``StragglerMonitor``), inject posit NaR codes into a slot's live KV rows
  (exercises the quarantine + degradation path), raise preemption mid-stream
  (SIGTERM or in-process flag; exercises drain-then-snapshot), and fail
  checkpoint IO N times (exercises ``with_retries`` inside the manager).
  Faults trigger on ``engine.steps`` so runs are reproducible.
* :class:`DegradationController` — the engine's ``watchdog``: consumes the
  ``NumericsWatcher`` health rows after each drift check and, for any site
  with a *fresh* breach (NaR rate over limit, or drift over threshold),
  steps that site one rung down the precision-escalation ladder

      packed-p8  ->  p8  ->  p16  ->  float bypass

  applied as an exact-path :class:`LayerRule` overlay prepended to the
  serving :class:`PrecisionPolicy` and hot-swapped via
  ``engine.apply_policy`` (weight formats only — the KV-cache format is
  pinned, so the live cache stays valid).  Every step emits a kind-tagged
  event (``nar`` / ``drift``) for the operator log.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.core.policy import LayerRule, PrecisionPolicy
from repro.core.types import PositFmt

__all__ = ["EngineSnapshotter", "FaultPlan", "DegradationController",
           "next_rung"]


# ---------------------------------------------------------------- snapshots ----

class EngineSnapshotter:
    """Cadenced async engine snapshots + restore, over ``CheckpointManager``.

    ``on_step(engine)`` (called by the engine at the end of every decode
    step) saves when ``engine.steps`` crosses the cadence; :meth:`force`
    saves unconditionally and blocks until durable (the preemption drain
    path).  Snapshots are stored raw (``fmt=None``): re-encoding the KV
    codes through a checkpoint codec would round-trip them and break the
    bit-identical-continuation contract.
    """

    def __init__(self, ckpt_dir: str, *, every: int = 256, keep: int = 3,
                 metrics=None, retries: int = 2,
                 retry_base_delay: float = 0.05, pre_save=None):
        if every < 1:
            raise ValueError(f"snapshot cadence must be >= 1, got {every}")
        self.every = every
        self.metrics = metrics
        self.mgr = CheckpointManager(
            ckpt_dir, keep=keep, fmt=None, metrics=metrics,
            retries=retries, retry_base_delay=retry_base_delay,
            pre_save=pre_save)
        self.saves = 0
        self._last_step = None     # dedupe: force() then on_step() same step
        self._m_restore_s = None
        if metrics is not None:
            self._m_restore_s = metrics.histogram(
                "snapshot_restore_s", "wall time of one engine restore")

    def on_step(self, engine) -> None:
        if engine.steps % self.every == 0 and engine.steps != self._last_step:
            self.save(engine)

    def save(self, engine) -> None:
        """Queue an async snapshot of the engine's current state."""
        snap = engine.snapshot()
        self.mgr.save_async(engine.steps, snap["arrays"],
                            extra={"meta": snap["meta"]})
        self.saves += 1
        self._last_step = engine.steps

    def force(self, engine) -> None:
        """Snapshot now and block until it is durable on disk."""
        self.save(engine)
        self.mgr.wait()

    def restore_into(self, engine, *, now: float = 0.0) -> bool:
        """Restore the newest durable snapshot into ``engine``.

        Returns False when the directory holds no checkpoint (fresh start).
        The engine must already be constructed with the same model / policy /
        grid — restore asserts the config fingerprint.
        """
        t0 = time.perf_counter()
        got = self.mgr.restore_or_none(engine.snapshot_like())
        if got is None:
            return False
        arrays, manifest = got
        engine.restore({"arrays": arrays, "meta": manifest["extra"]["meta"]},
                       now=now)
        if self._m_restore_s is not None:
            self._m_restore_s.observe(time.perf_counter() - t0)
        return True

    def wait(self) -> None:
        self.mgr.wait()

    def close(self) -> None:
        self.mgr.close()


# ----------------------------------------------------------- fault injection ----

def _nar_code(leaf):
    """The value that decodes to NaR/NaN for one KV leaf dtype.

    KV code arrays are uint8 (p8: NaR = 0x80) or uint16 (p16: NaR = 0x8000);
    a float KV cache (posit disabled) takes NaN directly.
    """
    if leaf.dtype == jnp.uint8:
        return jnp.uint8(0x80)
    if leaf.dtype == jnp.uint16:
        return jnp.uint16(0x8000)
    return jnp.asarray(jnp.nan, leaf.dtype)


@dataclasses.dataclass
class FaultPlan:
    """Deterministic chaos schedule, keyed on ``engine.steps``.

    Pass as ``ContinuousBatchingEngine(faults=...)``; the engine calls
    :meth:`on_step` at the top of every decode step (before the decode
    executes, so an injected NaR is live in that step's computation).  Use
    :meth:`ckpt_pre_save` as ``EngineSnapshotter(pre_save=...)`` to make the
    next ``ckpt_fail_times`` checkpoint save attempts raise ``OSError``.

    Each trigger fires once; ``fired`` logs what happened when.
    """

    # stall: sleep stall_s before the decode at step stall_at_step
    stall_at_step: Optional[int] = None
    stall_s: float = 0.0
    # NaR injection: poison nar_count KV positions of slot nar_slot
    nar_at_step: Optional[int] = None
    nar_slot: int = 0
    nar_count: int = 4
    # preemption: SIGTERM to self (needs PreemptionSignal(install_sigterm=
    # True) in the process) or a direct flag via the preemption object
    preempt_at_step: Optional[int] = None
    use_sigterm: bool = False
    preemption: Optional[object] = None
    # checkpoint IO: next N save attempts raise OSError (consumed by
    # ckpt_pre_save, wired through CheckpointManager's pre_save hook)
    ckpt_fail_times: int = 0
    fired: List[dict] = dataclasses.field(default_factory=list)

    def on_step(self, engine) -> None:
        step = engine.steps
        if self.stall_at_step is not None and step == self.stall_at_step:
            self.stall_at_step = None
            self.fired.append({"kind": "stall", "step": step,
                               "stall_s": self.stall_s})
            time.sleep(self.stall_s)
        if self.nar_at_step is not None and step == self.nar_at_step:
            self.nar_at_step = None
            self.fired.append({"kind": "nar", "step": step,
                               "slot": self.nar_slot, "count": self.nar_count})
            engine.inject_nar_into(self.nar_slot, self.nar_count)
        if self.preempt_at_step is not None and step == self.preempt_at_step:
            self.preempt_at_step = None
            self.fired.append({"kind": "preempt", "step": step,
                               "sigterm": self.use_sigterm})
            if self.use_sigterm:
                os.kill(os.getpid(), signal.SIGTERM)
            elif self.preemption is not None:
                self.preemption.preempt()

    def ckpt_pre_save(self, step: int) -> None:
        """``CheckpointManager(pre_save=...)`` hook: fail the next
        ``ckpt_fail_times`` save attempts with ``OSError``."""
        if self.ckpt_fail_times > 0:
            self.ckpt_fail_times -= 1
            self.fired.append({"kind": "ckpt_fail", "step": step})
            raise OSError(f"injected checkpoint IO failure (step {step})")


# ------------------------------------------------------- graceful degradation ----

def next_rung(fmt: Optional[PositFmt], packed: bool):
    """One step down the precision-escalation ladder.

    Returns ``(fmt, packed, bypass)`` for the next-wider configuration, or
    ``None`` when already at float (nothing wider exists):

        packed-p8 -> p8 -> p16 -> float bypass
    """
    if fmt is None:
        return None                              # already float
    if fmt.nbits == 8 and packed:
        return (fmt, False, False)               # unpack: full-width p8 words
    if fmt.nbits == 8:
        return (PositFmt(16, max(fmt.es, 1)), False, False)
    return (None, False, True)                   # p16 -> float bypass


class DegradationController:
    """Numerics-driven precision escalation (the engine ``watchdog``).

    ``maybe_degrade(engine)`` runs after every drift check.  A site breaches
    when its *fresh* health row (``check_id == watcher.checks`` — stale rows
    from quiet windows never re-trigger) shows ``nar_rate`` over
    ``nar_rate_limit`` or a drift score over its calibrated threshold.  Each
    breach steps that one site down the ladder; unaffected sites keep their
    formats.  The overlay is an exact-path rule *prepended* to the policy's
    rule list, so it wins over the original schedule but leaves it intact.
    """

    def __init__(self, watcher, *, nar_rate_limit: float = 0.0,
                 max_rungs: int = 4, on_event: Optional[Callable] = None,
                 metrics=None):
        self.watcher = watcher
        self.nar_rate_limit = nar_rate_limit
        self.max_rungs = max_rungs
        self.on_event = on_event
        self.metrics = metrics
        self.events: List[dict] = []
        self._overrides: Dict[str, LayerRule] = {}   # site path -> live rule
        self._rungs: Dict[str, int] = {}             # site path -> steps taken
        self._last_check = 0

    def _breach_kind(self, h) -> Optional[str]:
        if h.nar_rate > self.nar_rate_limit:
            return "nar"
        if h.drifted:
            return "drift"
        return None

    def maybe_degrade(self, engine) -> int:
        """Step every freshly-breached site one rung; returns #sites stepped."""
        w = self.watcher
        if w.checks == self._last_check:
            return 0
        self._last_check = w.checks
        stepped = 0
        for path, h in sorted(w.health.items()):
            if h.check_id != w.checks:
                continue                 # stale row: no traffic this window
            kind = self._breach_kind(h)
            if kind is None or self._rungs.get(path, 0) >= self.max_rungs:
                continue
            if self._step_site(engine, path, kind, h):
                stepped += 1
        if stepped:
            engine.apply_policy(self._overlaid(engine.policy))
        return stepped

    def _current(self, engine, path):
        """(fmt, packed) the site currently runs under."""
        pol = engine.policy
        resolve = getattr(pol, "policy_for", None)
        site = resolve(path) if resolve is not None else pol
        return site.weights, bool(getattr(site, "pack_weights", False))

    def _step_site(self, engine, path: str, kind: str, h) -> bool:
        fmt, packed = self._current(engine, path)
        rung = next_rung(fmt, packed)
        if rung is None:
            return False                 # already at float: nowhere to go
        new_fmt, new_packed, bypass = rung
        self._overrides[path] = (
            LayerRule(path, None, bypass=True) if bypass
            else LayerRule(path, new_fmt, packed=new_packed))
        self._rungs[path] = self._rungs.get(path, 0) + 1
        ev = {"kind": kind, "site": path,
              "from": f"{fmt.name}{'(packed)' if packed else ''}"
                      if fmt else "float",
              "to": "float" if bypass
                    else f"{new_fmt.name}{'(packed)' if new_packed else ''}",
              "step": engine.steps, "check_id": h.check_id,
              "nar_rate": h.nar_rate, "drift_score": h.drift_score}
        self.events.append(ev)
        if self.metrics is not None:
            self.metrics.counter(
                "degradations",
                "precision-ladder steps, by trigger kind").inc(label=kind)
            self.metrics.gauge(
                "degraded_sites",
                "sites running wider than their scheduled format").set(
                    len(self._overrides))
        if self.on_event is not None:
            self.on_event(ev)
        return True

    def _overlaid(self, policy) -> PrecisionPolicy:
        """The serving policy with the live overrides prepended."""
        if not isinstance(policy, PrecisionPolicy):
            policy = PrecisionPolicy(base=policy, name="degraded")
        base_rules = tuple(r for r in policy.rules
                           if r.pattern not in self._overrides)
        return dataclasses.replace(
            policy, rules=tuple(self._overrides.values()) + base_rules,
            name=policy.name if policy.name.endswith("+degraded")
            else policy.name + "+degraded")
