"""Fault-tolerance runtime for 1000+-node posture.

Components (all host-side; device work stays pure JAX):

* ``with_retries``     — transient-failure retry with exponential backoff
                         (device OOM / interconnect hiccups / flaky hosts).
* ``PreemptionSignal`` — SIGTERM-style graceful-drain flag: on preemption the
                         loop finishes the in-flight step, force-checkpoints,
                         and exits with a resumable cursor.
* ``StragglerMonitor`` — per-step wall-time EWMA; a step slower than
                         ``threshold x`` the EWMA marks the host a straggler.
                         Mitigation hooks: (a) skip-and-log (deterministic
                         pipeline makes skipped steps reproducible cluster-
                         wide), (b) re-shard signal for elastic restart.
* ``FaultTolerantLoop``— glue: checkpoint-every-k, auto-resume, preemption
                         drain, straggler accounting, crash-equivalent restore
                         (exercised in tests by killing the loop mid-run).

Elasticity: checkpoints are topology-free (host npz + manifest), so a restore
may target any mesh; ``load_checkpoint(shardings=...)`` re-lays-out every leaf
(tested: save at one sharding, restore at another, bit-identical values).
"""
from __future__ import annotations

import dataclasses
import random
import signal
import threading
import time
from typing import Any, Callable, Optional

from repro.checkpoint.ckpt import CheckpointManager


def with_retries(fn: Callable, *, retries: int = 3, base_delay: float = 0.5,
                 max_delay: float = 30.0,
                 retryable=(RuntimeError, OSError), on_retry=None,
                 jitter: bool = True, rng: Optional[random.Random] = None):
    """Call fn(); on retryable failure, back off and retry.

    ``retryable`` is an exception *allowlist*: only those types are retried —
    a ``KeyboardInterrupt`` or ``AssertionError`` (a bug, not a transient)
    propagates on the first throw.  Backoff is exponential with decorrelated
    jitter (AWS architecture-blog style): each sleep is drawn uniformly from
    ``[base_delay, 3 * previous_sleep]``, capped at ``max_delay`` — a fleet
    of retrying hosts decorrelates instead of thundering in lockstep.
    ``jitter=False`` keeps the deterministic ``base_delay * 2**attempt``
    schedule (tests); ``rng`` pins the jitter stream.
    """
    attempt = 0
    sleep = base_delay
    draw = (rng or random).uniform
    while True:
        try:
            return fn()
        except retryable as e:
            attempt += 1
            if attempt > retries:
                raise
            if on_retry:
                on_retry(attempt, e)
            if jitter:
                sleep = min(max_delay, draw(base_delay, max(sleep * 3.0,
                                                            base_delay)))
            else:
                sleep = min(max_delay, base_delay * (2 ** (attempt - 1)))
            time.sleep(sleep)


class PreemptionSignal:
    """Graceful-drain flag, optionally hooked to SIGTERM."""

    def __init__(self, install_sigterm: bool = False):
        self._flag = threading.Event()
        if install_sigterm:
            signal.signal(signal.SIGTERM, lambda *_: self._flag.set())

    def preempt(self):
        self._flag.set()

    @property
    def triggered(self) -> bool:
        return self._flag.is_set()


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 3.0
    alpha: float = 0.2
    _ewma: Optional[float] = None
    events: int = 0

    def observe(self, step_time: float) -> bool:
        """Record a step time; True if this step straggled."""
        if self._ewma is None:
            self._ewma = step_time
            return False
        is_straggler = step_time > self.threshold * self._ewma
        if is_straggler:
            self.events += 1
            # do NOT fold outliers into the baseline
            return True
        self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_time
        return False


class FaultTolerantLoop:
    """Checkpointed, preemptible, straggler-aware step loop."""

    def __init__(self, *, ckpt: CheckpointManager,
                 save_every: int = 50,
                 preemption: Optional[PreemptionSignal] = None,
                 straggler: Optional[StragglerMonitor] = None):
        self.ckpt = ckpt
        self.save_every = save_every
        self.preemption = preemption or PreemptionSignal()
        self.straggler = straggler or StragglerMonitor()
        self.stats = {"steps": 0, "saves": 0, "stragglers": 0, "resumed_from": None}

    def resume(self, state_like: Any, shardings: Any = None):
        """Returns (state, start_step): restored if a checkpoint exists."""
        got = self.ckpt.restore_or_none(state_like, shardings)
        if got is None:
            return state_like, 0
        state, manifest = got
        start = int(manifest["extra"].get("next_step", manifest["step"] + 1))
        self.stats["resumed_from"] = manifest["step"]
        return state, start

    def run(self, state: Any, step_fn: Callable[[Any, int], Any], *,
            start_step: int, num_steps: int,
            on_step: Optional[Callable] = None) -> tuple[Any, int]:
        """Run up to num_steps; returns (state, next_step). Exits early on
        preemption (after a forced checkpoint)."""
        step = start_step
        end = start_step + num_steps
        while step < end:
            t0 = time.monotonic()
            state = step_fn(state, step)
            dt = time.monotonic() - t0
            if self.straggler.observe(dt):
                self.stats["stragglers"] += 1
            self.stats["steps"] += 1
            step += 1
            if on_step:
                on_step(step, state, dt)
            if step % self.save_every == 0:
                self.ckpt.save_async(step, state, extra={"next_step": step})
                self.stats["saves"] += 1
            if self.preemption.triggered:
                self.ckpt.save_async(step, state, extra={"next_step": step,
                                                         "preempted": True})
                self.ckpt.wait()
                self.stats["saves"] += 1
                break
        return state, step
